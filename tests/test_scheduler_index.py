"""Differential tests: the indexed FlowMeshScheduler.schedule must produce
proposal sequences IDENTICAL to the retained naive oracle
(``schedule_reference``) — same (worker, bucket, groups) picks in the same
order with bit-equal utilities — over randomized pools, fleets, warm state,
and slot-exhaustion orders.

The scenario space is driven by one integer seed so the same generator
serves both the always-running seeded sweep and the hypothesis property
(hypothesis is optional in this environment; when present it explores and
shrinks seeds far beyond the fixed sweep).
"""
from __future__ import annotations

import random

import pytest

from repro.core.cost_model import DEVICE_CLASSES, MODEL_SIZES
from repro.core.dag import OpType, OperatorSpec
from repro.core.scheduler import (FlowMeshScheduler, _EXEC_CACHE,
                                  estimate_exec, _estimate_cached)
from repro.core.worker import DispatchBatch, ExecutionGroup, Worker, WorkerState

_GPU_OPS = [OpType.GENERATE, OpType.SCORE, OpType.EVAL,
            OpType.SFT, OpType.DPO, OpType.PPO]
_CPU_OPS = [OpType.TOOL, OpType.DATA_PREP, OpType.AGGREGATE]
_MODELS = sorted(MODEL_SIZES)
_DEVS = sorted(DEVICE_CLASSES)


def _spec(rng: random.Random, i: int) -> OperatorSpec:
    if rng.random() < 0.25:
        op = rng.choice(_CPU_OPS)
        model, rc = "", "cpu"
    else:
        op = rng.choice(_GPU_OPS)
        model = rng.choice(_MODELS)
        rc = rng.choice(["gpu.small", "gpu.medium", "cpu"])
    params: dict = {}
    if rng.random() < 0.5:
        params["max_batch"] = rng.randint(1, 24)
    if op in (OpType.SFT, OpType.DPO, OpType.PPO) and rng.random() < 0.5:
        params["lora"] = rng.random() < 0.5
    if rng.random() < 0.2:
        params["min_vram_gb"] = rng.choice([4.0, 16.0, 48.0, 200.0])
    if rng.random() < 0.15:
        params["affinity"] = tuple(rng.sample(_DEVS, rng.randint(1, 2)))
    if rng.random() < 0.15:
        params["anti_affinity"] = tuple(rng.sample(_DEVS, 1))
    return OperatorSpec(
        name=f"op{i}", op_type=op, model_id=model, params=params,
        resource_class=rc,
        tokens_in=rng.choice([64, 256, 1024]),
        tokens_out=rng.choice([16, 128, 512]),
        train_tokens=rng.choice([0, 2048, 65536]))


def _scenario(seed: int):
    """One random (pending, workers) pair plus pre-warmed fleet state."""
    rng = random.Random(seed)
    n_buckets = rng.randint(0, 10)
    pending: dict[str, list[ExecutionGroup]] = {}
    all_hashes: list[str] = []
    for i in range(n_buckets):
        spec = _spec(rng, i)
        hx = spec.h_exec()
        groups = []
        for j in range(rng.randint(1, 30)):
            ih = tuple(f"h{seed}-{i}-{j}-{k}" for k in range(rng.randint(0, 3)))
            all_hashes.extend(ih)
            groups.append(ExecutionGroup(
                h_task=f"t{i}-{j}", h_exec=hx, spec=spec, input_hashes=ih,
                ready_at=float(j)))
        pending[hx] = groups
    workers = []
    for i in range(rng.randint(0, 5)):
        dev = DEVICE_CLASSES[rng.choice(_DEVS)]
        w = Worker(f"w{i}", dev, now=0.0)
        w.state = (WorkerState.ACTIVE if rng.random() < 0.9
                   else rng.choice([WorkerState.PROVISIONING,
                                    WorkerState.DRAINING]))
        # warm state: resident models, artifact cache, hot lanes
        for hx, groups in pending.items():
            spec = groups[0].spec
            if spec.model_id and rng.random() < 0.4:
                w.make_resident(spec.h_model, spec.model_id)
            if rng.random() < 0.3:
                w.served_execs.add(hx)
        if all_hashes:
            w.local_cache.update(
                rng.sample(all_hashes,
                           rng.randint(0, min(20, len(all_hashes)))))
        # pre-consume slots so rounds start at varying remaining capacity
        for _ in range(rng.randint(0, 2)):
            w.admit(DispatchBatch(batch_id=-1, h_exec="warmup", groups=[],
                                  worker_id=w.worker_id, admitted_at=0.0))
        workers.append(w)
    return pending, workers


def _assert_identical(seed: int) -> None:
    pending, workers = _scenario(seed)
    sched = FlowMeshScheduler(
        w_t=random.Random(seed ^ 0xBEEF).choice([1.0, 2.0]),
        w_c=random.Random(seed ^ 0xCAFE).choice([0.0, 0.5, 2.0]),
        w_l=0.5)
    ref = sched.schedule_reference(
        {h: list(gs) for h, gs in pending.items()}, workers, 0.0)
    idx = sched.schedule(
        {h: list(gs) for h, gs in pending.items()}, workers, 0.0)
    assert len(idx) == len(ref), f"seed {seed}: {len(idx)} != {len(ref)}"
    for n, (a, b) in enumerate(zip(ref, idx)):
        assert b.worker.worker_id == a.worker.worker_id, (seed, n)
        assert b.h_exec == a.h_exec, (seed, n)
        assert b.utility == a.utility, (seed, n)   # bit-equal, not approx
        assert [id(g) for g in b.groups] == [id(g) for g in a.groups], (seed, n)
        assert b.speculative == a.speculative


def test_differential_seeded_sweep():
    """Always-on deterministic sweep (hypothesis is optional here)."""
    for seed in range(300):
        _assert_identical(seed)


def test_differential_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    given, settings, st = hyp.given, hyp.settings, hyp.strategies

    @given(st.integers(min_value=0, max_value=2**32 - 1))
    @settings(max_examples=200, deadline=None)
    def prop(seed):
        _assert_identical(seed)

    prop()


def test_slot_exhaustion_order():
    """More buckets than total fleet slots: the round must stop exactly when
    capacity runs out, picking the same winners in the same order."""
    for seed in (7, 42, 1337):
        pending, _ = _scenario(seed)
        if not pending:
            continue
        dev = DEVICE_CLASSES["rtx4090-48g"]
        w = Worker("only", dev, now=0.0)
        w.state = WorkerState.ACTIVE
        sched = FlowMeshScheduler()
        ref = sched.schedule_reference(
            {h: list(gs) for h, gs in pending.items()}, [w], 0.0)
        idx = sched.schedule(
            {h: list(gs) for h, gs in pending.items()}, [w], 0.0)
        assert [(p.h_exec, p.utility) for p in idx] \
            == [(p.h_exec, p.utility) for p in ref]
        assert len(idx) <= w.MAX_QUEUED_SLICES


def test_subclass_override_falls_back_to_reference():
    """A policy subclass that changes the objective must bypass the index
    (whose hoisted arithmetic mirrors the stock Eq. 1 only)."""
    class Inverted(FlowMeshScheduler):
        def utility(self, spec, groups, w):
            return -super().utility(spec, groups, w)

    pending, workers = _scenario(11)
    sched = Inverted()
    ref = sched.schedule_reference(
        {h: list(gs) for h, gs in pending.items()}, workers, 0.0)
    idx = sched.schedule(
        {h: list(gs) for h, gs in pending.items()}, workers, 0.0)
    assert [(p.h_exec, p.utility) for p in idx] \
        == [(p.h_exec, p.utility) for p in ref]


def test_estimate_cache_is_transparent():
    """Memoized estimates return the exact floats of the uncached call."""
    _EXEC_CACHE.clear()
    spec = OperatorSpec(name="g", op_type=OpType.GENERATE,
                        model_id="llama-3.2-1b")
    dev = DEVICE_CLASSES["h100-nvl-94g"]
    for hot in (False, True):
        for batch in (1, 8, 24):
            assert _estimate_cached(spec, batch, dev, hot) \
                == estimate_exec(spec, batch, dev, hot=hot)
            # second call hits the cache; must be identical, not just close
            assert _estimate_cached(spec, batch, dev, hot) \
                == estimate_exec(spec, batch, dev, hot=hot)


def test_worker_queued_counter_invariant():
    """The O(1) queued-slices counter tracks the queue contents exactly."""
    w = Worker("w", DEVICE_CLASSES["rtx4090-24g"], now=0.0)
    w.state = WorkerState.ACTIVE

    def truth():
        return sum(len(q) for q in w.queues.values()) \
            + (1 if w.current else 0)

    rng = random.Random(3)
    for step in range(200):
        roll = rng.random()
        if roll < 0.5:
            w.admit(DispatchBatch(batch_id=step, h_exec=f"x{rng.randint(0, 3)}",
                                  groups=[], worker_id="w", admitted_at=0.0))
        elif roll < 0.8:
            w.next_batch()
        else:
            w.drain()
        assert w.queued_slices() == truth(), step
