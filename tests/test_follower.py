"""Warm-standby follower fabric: ref-watch, tailing, fencing, promotion.

The contract under test (DESIGN.md §10):

  * a follower tailing the primary's journal equals a fresh
    ``restore_from_journal`` of the same chain at **every segment
    boundary** — including across primary-side compaction (rewritten tail
    segments fold idempotently by bus seq) and scheduled retention firing
    mid-tail (snapshot v2 re-bootstrap + ``feed_truncated`` markers
    surfaced through the follower's cursors);
  * promotion is an atomic epoch-bumping compare-and-set on the head ref:
    after it, a zombie primary's appends raise ``RefFencedError`` and the
    chain stays exactly where the promotion left it;
  * a crash at any write boundary of the promotion swap leaves the old
    entry intact, and a retry converges.
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.core import events as E
from repro.core.cas import CAS, DiskCAS, RefFencedError
from repro.core.journal import HEAD_REF, EventJournal
from repro.fabric import (FabricAPI, FabricService, FollowerAPI,
                          FollowerFabric, RetentionPolicy, TenantQuota)

from harness import (DEVICES, QUOTAS, Crash, CrashingCAS, build_service,
                     dual_service, observe, restore_fresh, run_schedule,
                     spec_doc, assert_cursor_contract)


# ---------------------------------------------------------------------------
# ref entries, fencing, watch_ref
# ---------------------------------------------------------------------------
class TestRefPrimitives:
    @pytest.fixture(params=["memory", "disk"])
    def cas(self, request, tmp_path):
        if request.param == "memory":
            return CAS()
        return DiskCAS(str(tmp_path / "cas"))

    def test_ref_entry_round_trip(self, cas):
        assert cas.ref_entry("r") == (None, 0)
        cas.set_ref("r", "a" * 20)
        assert cas.ref_entry("r") == ("a" * 20, 0)
        cas.set_ref("r", "b" * 20, epoch=3)
        assert cas.ref_entry("r") == ("b" * 20, 3)
        # epoch-less write preserves the stored epoch (legacy callers)
        cas.set_ref("r", "c" * 20)
        assert cas.ref_entry("r") == ("c" * 20, 3)

    def test_append_fencing(self, cas):
        cas.set_ref("r", "a" * 20, epoch=2)
        cas.set_ref("r", "b" * 20, epoch=2)      # same epoch appends freely
        with pytest.raises(RefFencedError):
            cas.set_ref("r", "c" * 20, epoch=1)  # stale writer refused
        assert cas.get_ref("r") == "b" * 20

    def test_compare_and_set(self, cas):
        cas.set_ref("r", "a" * 20, epoch=1)
        with pytest.raises(RefFencedError):      # wrong expected epoch
            cas.set_ref("r", "a" * 20, epoch=2, expect_epoch=0)
        with pytest.raises(RefFencedError):      # wrong expected key
            cas.set_ref("r", "b" * 20, epoch=2, expect_epoch=1,
                        expect_key="x" * 20)
        cas.set_ref("r", "a" * 20, epoch=2, expect_epoch=1,
                    expect_key="a" * 20)
        assert cas.ref_entry("r") == ("a" * 20, 2)

    def test_watch_ref_immediate_and_timeout(self, cas):
        assert cas.watch_ref("r", since=None, timeout_s=0.05,
                             poll_interval_s=0.01) is None
        cas.set_ref("r", "a" * 20)
        # already-different returns without blocking
        assert cas.watch_ref("r", since=None, timeout_s=5) == "a" * 20
        assert cas.watch_ref("r", since="zzz", timeout_s=5) == "a" * 20
        # unchanged: times out
        assert cas.watch_ref("r", since="a" * 20, timeout_s=0.05,
                             poll_interval_s=0.01) is None

    def test_watch_ref_wakes_on_advance(self, cas):
        cas.set_ref("r", "a" * 20)
        got = []
        t = threading.Thread(target=lambda: got.append(
            cas.watch_ref("r", since="a" * 20, timeout_s=5,
                          poll_interval_s=0.01)))
        t.start()
        time.sleep(0.05)
        cas.set_ref("r", "b" * 20)
        t.join(timeout=5)
        assert got == ["b" * 20]

    def test_legacy_single_line_ref_reads_epoch_zero(self, tmp_path):
        cas = DiskCAS(str(tmp_path / "cas"))
        cas.set_ref("legacy", "a" * 20)
        with open(cas._ref_path("legacy"), "w") as f:
            f.write("d" * 20)                    # pre-epoch file format
        assert cas.ref_entry("legacy") == ("d" * 20, 0)

    def test_cross_instance_watch(self, tmp_path):
        """Two DiskCAS objects on one dir = the dual-process topology."""
        a = DiskCAS(str(tmp_path / "cas"))
        b = DiskCAS(str(tmp_path / "cas"))
        a.set_ref("r", "a" * 20, epoch=1)
        assert b.ref_entry("r") == ("a" * 20, 1)
        got = []
        t = threading.Thread(target=lambda: got.append(
            b.watch_ref("r", since="a" * 20, timeout_s=5,
                        poll_interval_s=0.01)))
        t.start()
        time.sleep(0.05)
        a.set_ref("r", "b" * 20, epoch=1)
        t.join(timeout=5)
        assert got == ["b" * 20]
        # and b's stale write is fenced by a's epoch bump
        a.set_ref("r", "b" * 20, epoch=2, expect_epoch=1)
        with pytest.raises(RefFencedError):
            b.set_ref("r", "c" * 20, epoch=1)


# ---------------------------------------------------------------------------
# journal epoch plumbing
# ---------------------------------------------------------------------------
class TestJournalEpoch:
    def test_journal_adopts_stored_epoch(self):
        cas = CAS()
        j = EventJournal(cas, batch_size=1)
        assert j.epoch == 0
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        cas.set_ref(HEAD_REF, cas.get_ref(HEAD_REF), epoch=4,
                    expect_epoch=0)
        assert EventJournal(cas).epoch == 4

    def test_stale_epoch_flush_fenced(self):
        cas = CAS()
        j = EventJournal(cas, batch_size=1)
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        head = cas.get_ref(HEAD_REF)
        cas.set_ref(HEAD_REF, head, epoch=1, expect_epoch=0)
        zombie = EventJournal(cas, batch_size=1, epoch=0)
        with pytest.raises(RefFencedError):
            zombie.on_event(E.WorkflowSubmitted(time=1.0, dag_id="z",
                                                tenant="t"))
        assert cas.get_ref(HEAD_REF) == head     # chain untouched
        # the current-epoch owner keeps appending
        current = EventJournal(cas, batch_size=1)
        current.on_event(E.WorkflowSubmitted(time=2.0, dag_id="k",
                                             tenant="t"))
        assert cas.get_ref(HEAD_REF) != head

    def test_claim_fences_prior_owner(self):
        """Ownership is an explicit epoch bump, not ref adoption — so a
        supervisor-restarted copy of a fenced primary cannot silently
        regain write access by re-reading the current epoch."""
        cas = CAS()
        j1 = EventJournal(cas, batch_size=1)
        assert j1.claim() == 1
        j1.on_event(E.WorkflowSubmitted(time=0.0, dag_id="a", tenant="t"))
        j2 = EventJournal(cas, batch_size=1)
        assert j2.epoch == 1                 # adoption alone is read-grade
        assert j2.claim() == 2               # ...ownership is the bump
        j2.on_event(E.WorkflowSubmitted(time=1.0, dag_id="b", tenant="t"))
        with pytest.raises(RefFencedError):
            j1.on_event(E.WorkflowSubmitted(time=2.0, dag_id="c",
                                            tenant="t"))


# ---------------------------------------------------------------------------
# follower tailing ≡ restore, at every segment boundary
# ---------------------------------------------------------------------------
SCHEDULE = [("submit", 0, 0), ("pump", 8), ("submit", 1, 0), ("drain",),
            ("submit", 2, 1), ("submit", 0, 2), ("pump", 20), ("cancel", 2),
            ("drain",), ("compact", 1), ("submit", 1, 3), ("drain",)]


def _event_sourced_projection(svc) -> dict:
    """What a follower can (and must) reproduce of a live primary: per-job
    feeds and per-tenant usage accounting — engine-local meters (pool
    stats, latency percentiles) are process state, not replicated state."""
    tenants = sorted({r.tenant for r in svc.jobs.values()})

    def usage(t):
        return {k: v for k, v in svc.usage(t).items()
                if k not in ("pool", "latency")}

    return {
        "feeds": {jid: {k: v for k, v in svc.events(jid).items()
                        if k != "status"}
                  for jid in sorted(svc.jobs)},
        "usage": {t: usage(t) for t in tenants},
    }


class TestFollowerTailing:
    def test_equivalence_at_every_segment_boundary(self, tmp_path):
        """Dual-process topology on disk: the primary's FabricService writes
        through one DiskCAS instance, the follower tails a *separate*
        DiskCAS instance over the same directory. After every schedule step
        (journal flushed => segment boundary) the follower reproduces the
        primary's event-sourced state — feeds, usage accounting, terminal
        job views — and at every quiescent boundary (drain) it additionally
        equals a fresh ``restore_from_journal`` byte for byte. (Mid-flight
        the two legally differ: restore interrupts live jobs, a follower
        keeps them open — they are still running on the primary.)"""
        primary_cas = DiskCAS(str(tmp_path / "cas"))
        svc = build_service(primary_cas, batch_size=3)
        follower = FollowerFabric(DiskCAS(str(tmp_path / "cas")),
                                  batch_size=3)
        quiescent = 0
        for step in SCHEDULE:
            run_schedule(svc, [step])
            svc.journal.flush()              # a durable segment boundary
            follower.catch_up()
            assert _event_sourced_projection(follower.view) == \
                _event_sourced_projection(svc), f"diverged after {step}"
            for jid, rec in svc.jobs.items():
                primary_view = svc.job(jid)
                if primary_view["status"] in ("completed", "cancelled",
                                              "rejected"):
                    assert follower.view.job(jid) == primary_view, step
                else:
                    # live on the primary: the follower synthesizes the
                    # same queued/running answer from op events alone — a
                    # job is `running` the moment any op left `pending`,
                    # which coincides with the primary's arrival-based view
                    # at every flushed boundary
                    assert follower.view.job(jid)["status"] == \
                        primary_view["status"], step
            if step == ("drain",):
                quiescent += 1
                assert observe(follower.view) == observe(
                    restore_fresh(primary_cas)), f"diverged after {step}"
        assert quiescent == 3
        status = follower.replication_status()
        assert status["caught_up"] is True
        assert status["lag"] == {"segments": 0, "bytes": 0, "events": 0}

    def test_lag_reporting(self):
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        follower = FollowerFabric(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        caught = follower.replication_status()
        assert caught["caught_up"] and caught["lag"]["events"] == 0
        run_schedule(svc, [("submit", 1, 1), ("drain",)])
        behind = follower.replication_status()
        assert not behind["caught_up"]
        assert behind["lag"]["segments"] > 0
        assert behind["lag"]["bytes"] > 0
        assert behind["lag"]["events"] > 0
        follower.catch_up()
        assert follower.replication_status()["lag"]["events"] == 0

    def test_rebootstrap_after_primary_compacts_past_follower(self):
        """A compaction cut beyond the follower's position forces a snapshot
        re-bootstrap; state still equals a fresh restore."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        follower = FollowerFabric(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        run_schedule(svc, [("submit", 1, 1), ("submit", 2, 2), ("drain",)])
        svc.compact(keep_segments=0)         # folds events follower lacks
        out = follower.catch_up()
        assert out["bootstrapped"] is True
        assert follower.bootstraps == 1
        assert observe(follower.view) == observe(restore_fresh(cas))

    def test_retention_firing_on_primary_mid_tail(self):
        """Scheduled retention (auto compact + gc) fires on the primary
        while the follower is behind: the follower comes back through the
        v2 snapshot, applies its own windows, and its cursors surface the
        ``feed_truncated`` markers — never silent loss (checked against the
        uncompacted shadow's ground-truth feeds)."""
        retention = RetentionPolicy(feed_window=4, compact_every_segments=4,
                                    keep_segments=1)
        svc, shadow = dual_service(batch_size=3, retention=retention)
        cas = svc.journal.cas
        follower = FollowerFabric(cas, retention=retention, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        # primary keeps going: enough history that maybe_retain fires
        run_schedule(svc, [("submit", 1, 1), ("submit", 2, 2), ("drain",),
                           ("submit", 0, 3), ("submit", 1, 0), ("drain",)])
        assert svc.auto_compactions > 0      # retention really fired
        shadow.flush()
        follower.catch_up()
        assert observe(follower.view) == observe(
            restore_fresh(cas, retention=retention))
        # ground truth: the untrimmed shadow feeds
        full = restore_fresh(cas, ref="shadow-head")
        truncated = 0
        for jid in follower.view.jobs:
            resp = follower.view.events(jid, since=-1)
            assert_cursor_contract(resp, full._feeds.get(jid, []), -1)
            truncated += bool(resp.get("truncated"))
        assert truncated > 0                 # windows actually truncated

    def test_follower_adopts_operator_doc_changes(self):
        """Quota + retention written through by the primary (the operator
        API path) are live-adopted by an unpinned follower on catch-up."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        follower = FollowerFabric(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        svc.set_quota("newco", TenantQuota(weight=7.0))
        svc.set_retention(RetentionPolicy(feed_window=2))
        follower.catch_up()
        assert follower.admission.quotas["newco"].weight == 7.0
        assert follower.retention.feed_window == 2
        for feed in follower.state.feeds.values():
            assert len(feed) <= 2

    def test_config_propagates_without_journal_traffic(self):
        """Operator-config writes move their own ref, not the journal head;
        the reload path the tail loop runs on idle wake-ups must adopt them
        even when no segment ever flushes."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower = FollowerFabric(cas, batch_size=3)
        follower.catch_up()
        head = cas.get_ref(HEAD_REF)
        svc.set_retention(RetentionPolicy(feed_window=1))  # no append
        assert cas.get_ref(HEAD_REF) == head
        assert follower._maybe_reload_config() is True
        follower._sync_view()
        assert follower.retention.feed_window == 1
        assert follower.view.retention_policy.feed_window == 1
        for feed in follower.state.feeds.values():
            assert len(feed) <= 1
        assert follower._maybe_reload_config() is False    # idempotent


# ---------------------------------------------------------------------------
# promotion
# ---------------------------------------------------------------------------
class TestPromotion:
    def _primary_with_history(self, cas):
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("submit", 1, 1), ("drain",),
                           ("submit", 2, 2), ("drain",)])
        return svc

    def test_promote_after_kill_serves_same_state(self, tmp_path):
        primary_cas = DiskCAS(str(tmp_path / "cas"))
        svc = self._primary_with_history(primary_cas)
        pre_kill = observe(svc)
        pre_usage = {t: svc.admission.usage_snapshot(t)
                     for t in ("acme", "globex", "initech")}
        del svc                              # the kill (journal is drained)
        follower = FollowerFabric(DiskCAS(str(tmp_path / "cas")),
                                  batch_size=3)
        follower.catch_up()
        promoted = follower.promote()
        assert promoted.journal.epoch == 1
        assert primary_cas.ref_entry(HEAD_REF)[1] == 1
        post = observe(promoted)
        # engine-local meters (pool stats, latency percentiles) die with the
        # old process; everything event-sourced must match exactly
        for jid, view in pre_kill["jobs"].items():
            assert post["jobs"][jid] == view
        assert post["lineage"] == pre_kill["lineage"]
        assert post["feeds"] == pre_kill["feeds"]
        for t, u in pre_usage.items():
            assert promoted.admission.usage_snapshot(t) == u
        # read-write: new work runs and journals under the new epoch
        job = promoted.submit(spec_doc("acme", "after-promote"))
        promoted.run_until_idle()
        assert promoted.job(job["job_id"])["status"] == "completed"

    def test_promote_interrupts_in_flight_work(self):
        """Jobs live at the moment of the kill close out through the
        existing interrupt-on-restart path on the promoted fabric."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("pump", 4)])
        svc.journal.flush()                  # mid-flight durable history
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        [rec] = promoted.jobs.values()
        assert rec.cancelled and rec.error == "interrupted by fabric restart"

    def test_zombie_primary_is_fenced(self):
        cas = CAS()
        svc = self._primary_with_history(cas)
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        head = cas.get_ref(HEAD_REF)
        with pytest.raises(RefFencedError):  # zombie flush refused
            run_schedule(svc, [("submit", 0, 3), ("drain",)])
        assert cas.get_ref(HEAD_REF) == head
        with pytest.raises(RefFencedError):  # zombie compaction refused too
            svc.compact(keep_segments=0)
        assert cas.get_ref(HEAD_REF) == head
        # the promoted primary still owns the chain
        promoted.submit(spec_doc("globex", "post-fence"))
        promoted.run_until_idle()
        assert cas.get_ref(HEAD_REF) != head
        assert cas.ref_entry(HEAD_REF)[1] == 1

    def test_promote_on_empty_journal_still_takes_an_epoch(self):
        """No head to swap yet, but the promoted journal must carry epoch 1
        so an epoch-0 writer loses as soon as the chain materializes."""
        cas = CAS()
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        assert promoted.journal.epoch == 1
        promoted.submit(spec_doc("acme", "first"))
        promoted.run_until_idle()
        assert cas.ref_entry(HEAD_REF)[1] == 1
        stale = EventJournal(cas, batch_size=1, epoch=0)
        with pytest.raises(RefFencedError):
            stale.on_event(E.WorkflowSubmitted(time=0.0, dag_id="z",
                                               tenant="t"))

    def test_promote_is_idempotent(self):
        cas = CAS()
        self._primary_with_history(cas)
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        assert follower.promote() is promoted

    def test_promotion_crash_matrix(self):
        """Kill the promotion at every put/set_ref boundary of the swap:
        before the fence lands the old entry must be fully intact (the
        zombie primary is still the owner); wherever it dies, a retry
        converges to a promoted state equal to a fresh restore."""
        for op, after in (("set_ref", 0), ("set_ref", 1), ("put", 0)):
            inner = CAS()
            svc = self._primary_with_history(inner)
            pre_entry = inner.ref_entry(HEAD_REF)
            proxy = CrashingCAS(inner)
            follower = FollowerFabric(proxy, batch_size=3)
            follower.catch_up()
            proxy.arm(op, after)
            with pytest.raises(Crash):
                follower.promote()
            assert follower.promoted is None
            if (op, after) == ("set_ref", 0):
                # died before the fence: ownership never moved
                assert inner.ref_entry(HEAD_REF) == pre_entry
                run_schedule(svc, [("submit", 0, 3), ("drain",)])  # still ok
            else:
                # died after the fence: the zombie is already cut off
                assert inner.ref_entry(HEAD_REF)[1] == pre_entry[1] + 1
                with pytest.raises(RefFencedError):
                    run_schedule(svc, [("submit", 0, 3), ("drain",)])
            proxy.disarm()
            promoted = follower.promote()    # the retry
            assert promoted.journal.epoch >= 1
            assert observe(promoted) == observe(restore_fresh(inner)), \
                (op, after)


# ---------------------------------------------------------------------------
# the follower HTTP surface (in-process handler table)
# ---------------------------------------------------------------------------
class TestFollowerAPI:
    def _pair(self, cas=None):
        cas = cas if cas is not None else CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower = FollowerFabric(cas, batch_size=3)
        follower.catch_up()
        return svc, follower, FollowerAPI(follower)

    def test_reads_served_writes_409(self):
        svc, follower, api = self._pair()
        code, jobs = api.handle("GET", "/jobs")
        assert code == 200 and len(jobs["jobs"]) == 1
        jid = jobs["jobs"][0]["job_id"]
        code, job = api.handle("GET", f"/jobs/{jid}")
        assert code == 200 and job == svc.job(jid)
        code, feed = api.handle("GET", f"/jobs/{jid}/events?since=-1")
        assert code == 200 and feed["events"]
        code, repl = api.handle("GET", "/admin/replication")
        assert code == 200 and repl["role"] == "follower"
        for method, path in (("POST", "/workflows"),
                             ("POST", f"/jobs/{jid}/cancel"),
                             ("POST", "/admin/compact"),
                             ("PUT", "/admin/retention"),
                             ("PUT", "/tenants/acme/quota"),
                             ("POST", "/pump")):
            code, err = api.handle(method, path, {})
            assert code == 409 and err["error"] == "read_only_follower", path

    def test_promote_flips_read_write(self):
        svc, follower, api = self._pair()
        promoted_cb = []
        api.on_promoted = promoted_cb.append
        code, out = api.handle("POST", "/admin/promote", {})
        assert code == 200 and out["promoted"] and out["epoch"] == 1
        assert promoted_cb == [follower.promoted]
        code, repl = api.handle("GET", "/admin/replication")
        assert code == 200 and repl["role"] == "primary"
        assert repl["journal"]["epoch"] == 1
        code, job = api.handle("POST", "/workflows", {
            "spec": spec_doc("acme", "rw")})
        assert code == 201, job
        code, out2 = api.handle("POST", "/admin/promote", {})
        assert code == 409 and out2["error"] == "already_primary"
        # operator API now writes through (was 409 pre-promote)
        code, ret = api.handle("PUT", "/admin/retention", {"feed_window": 8})
        assert code == 200 and ret["policy"]["feed_window"] == 8

    def test_primary_api_replication_and_promote(self):
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        api = FabricAPI(svc)
        code, repl = api.handle("GET", "/admin/replication")
        assert code == 200 and repl["role"] == "primary"
        code, err = api.handle("POST", "/admin/promote", {})
        assert code == 409 and err["error"] == "already_primary"


# ---------------------------------------------------------------------------
# head-ref liveness lease + auto-election (DESIGN.md §14)
# ---------------------------------------------------------------------------
class FakeClock:
    """Deterministic wall clock shared by a leased journal and its
    followers — election timing becomes a pure function of ``advance``."""

    def __init__(self, t: float = 1000.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class TestLeasePrimitives:
    @pytest.fixture(params=["memory", "disk"])
    def cas(self, request, tmp_path):
        if request.param == "memory":
            return CAS()
        return DiskCAS(str(tmp_path / "cas"))

    def test_ref_lease_round_trip(self, cas):
        assert cas.ref_lease("r") == 0.0
        cas.set_ref("r", "a" * 20, lease_until=123.5)
        assert cas.ref_lease("r") == 123.5
        assert cas.ref_entry("r") == ("a" * 20, 0)   # entry shape unchanged
        # a lease-less rewrite *clears* the stored lease: a writer that does
        # not heartbeat (offline tool, shadow journal) must not leave its
        # predecessor's stale liveness claim behind
        cas.set_ref("r", "b" * 20)
        assert cas.ref_lease("r") == 0.0

    def test_lease_rides_the_epoch_cas(self, cas):
        cas.set_ref("r", "a" * 20, epoch=1, lease_until=50.0)
        cas.set_ref("r", "a" * 20, epoch=2, expect_epoch=1, lease_until=99.0)
        assert cas.ref_lease("r") == 99.0
        with pytest.raises(RefFencedError):          # fenced write: no stamp
            cas.set_ref("r", "a" * 20, epoch=1, lease_until=777.0)
        assert cas.ref_lease("r") == 99.0
        assert cas.ref_entry("r") == ("a" * 20, 2)

    def test_legacy_disk_ref_files_read_lease_zero(self, tmp_path):
        """v1 (<key>) and v2 (<key>\\n<epoch>) ref files predate the lease
        line; both must parse as "no lease" — never auto-promotable."""
        cas = DiskCAS(str(tmp_path / "cas"))
        cas.set_ref("r", "a" * 20, epoch=3, lease_until=9.0)
        path = cas._ref_path("r")
        with open(path, "w") as f:
            f.write("d" * 20)                        # v1
        assert cas.ref_entry("r") == ("d" * 20, 0)
        assert cas.ref_lease("r") == 0.0
        with open(path, "w") as f:
            f.write("e" * 20 + "\n7\n")              # v2
        assert cas.ref_entry("r") == ("e" * 20, 7)
        assert cas.ref_lease("r") == 0.0
        assert cas.ref_lease("never-written") == 0.0


class TestJournalLease:
    def _leased(self, ttl=6.0):
        cas, clock = CAS(), FakeClock()
        j = EventJournal(cas, batch_size=1, lease_ttl_s=ttl, clock=clock)
        return cas, clock, j

    def test_flush_and_claim_stamp_the_lease(self):
        cas, clock, j = self._leased(ttl=5.0)
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        assert cas.ref_lease(HEAD_REF) == clock.t + 5.0
        clock.advance(2.0)
        assert j.claim() == 1
        assert cas.ref_lease(HEAD_REF) == clock.t + 5.0

    def test_heartbeat_rate_limited_and_forceable(self):
        cas, clock, j = self._leased(ttl=6.0)
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        stamped = cas.ref_lease(HEAD_REF)
        assert j.heartbeat_lease() is False          # just wrote: < TTL/3
        clock.advance(1.0)
        assert j.heartbeat_lease() is False
        assert cas.ref_lease(HEAD_REF) == stamped    # no write happened
        assert j.heartbeat_lease(force=True) is True
        assert cas.ref_lease(HEAD_REF) == clock.t + 6.0
        clock.advance(2.5)                           # past TTL/3 again
        assert j.heartbeat_lease() is True
        assert cas.ref_lease(HEAD_REF) == clock.t + 6.0

    def test_heartbeat_noops_without_ttl_or_head(self):
        cas, clock = CAS(), FakeClock()
        assert EventJournal(cas).heartbeat_lease(force=True) is False
        j = EventJournal(cas, lease_ttl_s=5.0, clock=clock)
        assert j.heartbeat_lease(force=True) is False   # nothing published
        assert cas.ref_lease(HEAD_REF) == 0.0

    def test_fenced_heartbeat_raises(self):
        """A zombie primary's heartbeat must die with the same fence its
        appends do — it must not keep looking alive to the followers."""
        cas, clock, j = self._leased(ttl=5.0)
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        cas.set_ref(HEAD_REF, cas.get_ref(HEAD_REF), epoch=1, expect_epoch=0)
        clock.advance(5.0)
        with pytest.raises(RefFencedError):
            j.heartbeat_lease(force=True)

    def test_lease_less_journal_unchanged(self):
        cas = CAS()
        j = EventJournal(cas, batch_size=1)
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        assert cas.ref_lease(HEAD_REF) == 0.0        # opted out, no claim


class TestAutoElection:
    TTL = 6.0

    def _leased_primary(self, cas, clock):
        """A primary whose journal heartbeats a liveness lease."""
        journal = EventJournal(cas, batch_size=3, lease_ttl_s=self.TTL,
                               clock=clock)
        svc = FabricService(seed=7, cas=cas, device_classes=DEVICES,
                            journal=journal)
        for tenant, quota in QUOTAS.items():
            svc.set_quota(tenant, quota)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        svc.journal.flush()
        return svc

    def _follower(self, cas, clock, **kw):
        kw.setdefault("auto_promote", True)
        kw.setdefault("lease_ttl_s", self.TTL)
        return FollowerFabric(cas, batch_size=3, clock=clock, **kw)

    def test_fresh_lease_stands_down(self):
        cas, clock = CAS(), FakeClock()
        self._leased_primary(cas, clock)
        f = self._follower(cas, clock)
        f.catch_up()
        status = f.lease_status()
        assert status["held"] and not status["expired"]
        assert status["remaining_s"] == pytest.approx(self.TTL)
        assert f.maybe_elect() is None and f.promoted is None

    def test_single_follower_self_promotes(self):
        cas, clock = CAS(), FakeClock()
        svc = self._leased_primary(cas, clock)
        f = self._follower(cas, clock)
        f.catch_up()
        clock.advance(self.TTL + 1.0)        # the primary went silent
        assert f.lease_status()["expired"]
        new = f.maybe_elect()
        assert new is not None and f.promoted is new
        assert f.elections_won == 1 and f.elections_lost == 0
        assert cas.ref_entry(HEAD_REF)[1] == 1 == new.journal.epoch
        # the takeover stamped a fresh lease: rivals stand down instead of
        # re-electing over the winner, and the winner itself can later be
        # failed over by the same machinery
        assert cas.ref_lease(HEAD_REF) == clock.t + self.TTL
        # the silent primary is a zombie now: heartbeat and append fenced
        with pytest.raises(RefFencedError):
            svc.journal.heartbeat_lease(force=True)
        svc.journal.on_event(E.WorkflowSubmitted(time=9.0, dag_id="z",
                                                 tenant="acme"))
        with pytest.raises(RefFencedError):
            svc.journal.flush()
        # the winner serves read-write under the new epoch
        job = new.submit(spec_doc("acme", "post-election"))
        new.run_until_idle()
        assert new.job(job["job_id"])["status"] == "completed"
        # observability: status + metrics carry the election
        status = f.replication_status()
        assert status["auto_promote"] is True
        assert status["elections"] == {"won": 1, "lost": 0}
        assert 'fabric_elections_total{outcome="won"} 1' in f.metrics.render()

    def test_lease_less_head_never_auto_promoted(self):
        """A primary that does not heartbeat (legacy deploy, offline tool)
        opted out of auto-failover: only an operator promote moves it."""
        cas, clock = CAS(), FakeClock()
        svc = build_service(cas, batch_size=3)       # journal has no TTL
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        svc.journal.flush()
        f = self._follower(cas, clock)
        f.catch_up()
        assert f.lease_status() == {"held": False, "until": None,
                                    "remaining_s": None, "expired": False}
        clock.advance(1e9)
        assert f.maybe_elect() is None and f.promoted is None
        assert f.promote().journal.epoch == 1        # manual path still open

    def test_unarmed_follower_ignores_expiry(self):
        cas, clock = CAS(), FakeClock()
        self._leased_primary(cas, clock)
        f = self._follower(cas, clock, auto_promote=False)
        f.catch_up()
        clock.advance(self.TTL * 3)
        assert f.lease_status()["expired"]
        assert f.maybe_elect() is None and f.promoted is None

    def test_two_followers_exactly_one_wins(self):
        """The election race: both observe the same expired (key, epoch)
        and CAS concurrently — the fence admits exactly one."""
        cas, clock = CAS(), FakeClock()
        self._leased_primary(cas, clock)
        f1, f2 = self._follower(cas, clock), self._follower(cas, clock)
        f1.catch_up(), f2.catch_up()
        clock.advance(self.TTL + 2.0)
        _, epoch = cas.ref_entry(HEAD_REF)
        results: dict[str, object] = {}
        barrier = threading.Barrier(2)

        def race(name, f):
            barrier.wait()
            try:
                results[name] = f.promote(expect_epoch=epoch)
            except RefFencedError as exc:
                results[name] = exc

        threads = [threading.Thread(target=race, args=(n, f))
                   for n, f in (("f1", f1), ("f2", f2))]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        wins = {n for n, v in results.items()
                if not isinstance(v, RefFencedError)}
        assert len(results) == 2 and len(wins) == 1, results
        winner_f, loser_f = (f1, f2) if wins == {"f1"} else (f2, f1)
        assert winner_f.promoted is not None and loser_f.promoted is None
        assert cas.ref_entry(HEAD_REF)[1] == 1       # exactly one bump
        # the loser simply resumes tailing the winner's appends...
        winner = winner_f.promoted
        winner.submit(spec_doc("acme", "after-election"))
        winner.run_until_idle()
        winner.journal.flush()
        loser_f.catch_up()
        assert _event_sourced_projection(loser_f.view) == \
            _event_sourced_projection(winner)
        # ...and stands down at its next wake: the winner's lease is fresh
        assert loser_f.maybe_elect() is None

    def test_election_lost_mid_observation_resumes_tailing(self, monkeypatch):
        """A rival lands its takeover in the window between this follower's
        lease observation and its own CAS: the pinned promote is refused,
        the loss is counted, and the follower keeps tailing the winner."""
        cas, clock = CAS(), FakeClock()
        self._leased_primary(cas, clock)
        f = self._follower(cas, clock)
        f.catch_up()
        clock.advance(self.TTL + 1.0)
        _, epoch = cas.ref_entry(HEAD_REF)
        rival = self._follower(cas, clock)
        fired = []
        real_ref_lease = cas.ref_lease

        def racing_ref_lease(name):
            out = real_ref_lease(name)       # observed: held and expired
            if not fired:
                fired.append(True)
                rival.promote(expect_epoch=epoch)
            return out

        monkeypatch.setattr(cas, "ref_lease", racing_ref_lease)
        assert f.maybe_elect() is None
        assert f.elections_lost == 1 and f.promoted is None
        assert rival.promoted is not None
        assert 'fabric_elections_total{outcome="lost"} 1' in f.metrics.render()
        winner = rival.promoted
        winner.submit(spec_doc("acme", "rival-won"))
        winner.run_until_idle()
        winner.journal.flush()
        f.catch_up()
        assert _event_sourced_projection(f.view) == \
            _event_sourced_projection(winner)
        assert f.maybe_elect() is None       # fresh lease: stands down

    def test_promote_forwards_device_classes(self):
        """Regression: promote() used to drop the follower's pinned
        ``device_classes`` and restore with the defaults — the promoted
        engine's worker pool must be shaped like the standby was told."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        svc.journal.flush()
        follower = FollowerFabric(cas, batch_size=3,
                                  device_classes=("rtx4090-24g",))
        assert {w.dev.name for w in
                follower.view.engine.workers.values()} == {"rtx4090-24g"}
        promoted = follower.promote()
        assert {w.dev.name for w in
                promoted.engine.workers.values()} == {"rtx4090-24g"}

    def test_tail_loop_auto_promotes_and_notifies(self):
        """Real-time integration: a served standby's tail loop detects the
        expired lease on a timeout wake-up and elects itself — no head
        movement, no operator action."""
        cas = CAS()
        journal = EventJournal(cas, batch_size=3, lease_ttl_s=0.3)
        svc = FabricService(seed=7, cas=cas, device_classes=DEVICES,
                            journal=journal)
        for tenant, quota in QUOTAS.items():
            svc.set_quota(tenant, quota)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        svc.journal.flush()                  # last heartbeat, then "kill -9"
        promoted_cb = []
        f = FollowerFabric(cas, batch_size=3, auto_promote=True,
                           lease_ttl_s=0.3)
        f.on_promoted = promoted_cb.append
        stop, lock = threading.Event(), threading.RLock()
        t = threading.Thread(target=f.tail_loop, args=(stop, lock),
                             kwargs={"poll_interval_s": 0.01,
                                     "wake_every_s": 0.05}, daemon=True)
        t.start()
        deadline = time.time() + 30
        while f.promoted is None and time.time() < deadline:
            time.sleep(0.02)
        stop.set()
        t.join(timeout=10)
        assert f.promoted is not None and f.elections_won == 1
        assert promoted_cb == [f.promoted]
        assert cas.ref_entry(HEAD_REF)[1] == 1
