"""Warm-standby follower fabric: ref-watch, tailing, fencing, promotion.

The contract under test (DESIGN.md §10):

  * a follower tailing the primary's journal equals a fresh
    ``restore_from_journal`` of the same chain at **every segment
    boundary** — including across primary-side compaction (rewritten tail
    segments fold idempotently by bus seq) and scheduled retention firing
    mid-tail (snapshot v2 re-bootstrap + ``feed_truncated`` markers
    surfaced through the follower's cursors);
  * promotion is an atomic epoch-bumping compare-and-set on the head ref:
    after it, a zombie primary's appends raise ``RefFencedError`` and the
    chain stays exactly where the promotion left it;
  * a crash at any write boundary of the promotion swap leaves the old
    entry intact, and a retry converges.
"""
from __future__ import annotations

import threading
import time

import pytest

from repro.core import events as E
from repro.core.cas import CAS, DiskCAS, RefFencedError
from repro.core.journal import HEAD_REF, EventJournal
from repro.fabric import (FabricAPI, FollowerAPI, FollowerFabric,
                          RetentionPolicy, TenantQuota)

from harness import (Crash, CrashingCAS, build_service, dual_service,
                     observe, restore_fresh, run_schedule, spec_doc,
                     assert_cursor_contract)


# ---------------------------------------------------------------------------
# ref entries, fencing, watch_ref
# ---------------------------------------------------------------------------
class TestRefPrimitives:
    @pytest.fixture(params=["memory", "disk"])
    def cas(self, request, tmp_path):
        if request.param == "memory":
            return CAS()
        return DiskCAS(str(tmp_path / "cas"))

    def test_ref_entry_round_trip(self, cas):
        assert cas.ref_entry("r") == (None, 0)
        cas.set_ref("r", "a" * 20)
        assert cas.ref_entry("r") == ("a" * 20, 0)
        cas.set_ref("r", "b" * 20, epoch=3)
        assert cas.ref_entry("r") == ("b" * 20, 3)
        # epoch-less write preserves the stored epoch (legacy callers)
        cas.set_ref("r", "c" * 20)
        assert cas.ref_entry("r") == ("c" * 20, 3)

    def test_append_fencing(self, cas):
        cas.set_ref("r", "a" * 20, epoch=2)
        cas.set_ref("r", "b" * 20, epoch=2)      # same epoch appends freely
        with pytest.raises(RefFencedError):
            cas.set_ref("r", "c" * 20, epoch=1)  # stale writer refused
        assert cas.get_ref("r") == "b" * 20

    def test_compare_and_set(self, cas):
        cas.set_ref("r", "a" * 20, epoch=1)
        with pytest.raises(RefFencedError):      # wrong expected epoch
            cas.set_ref("r", "a" * 20, epoch=2, expect_epoch=0)
        with pytest.raises(RefFencedError):      # wrong expected key
            cas.set_ref("r", "b" * 20, epoch=2, expect_epoch=1,
                        expect_key="x" * 20)
        cas.set_ref("r", "a" * 20, epoch=2, expect_epoch=1,
                    expect_key="a" * 20)
        assert cas.ref_entry("r") == ("a" * 20, 2)

    def test_watch_ref_immediate_and_timeout(self, cas):
        assert cas.watch_ref("r", since=None, timeout_s=0.05,
                             poll_interval_s=0.01) is None
        cas.set_ref("r", "a" * 20)
        # already-different returns without blocking
        assert cas.watch_ref("r", since=None, timeout_s=5) == "a" * 20
        assert cas.watch_ref("r", since="zzz", timeout_s=5) == "a" * 20
        # unchanged: times out
        assert cas.watch_ref("r", since="a" * 20, timeout_s=0.05,
                             poll_interval_s=0.01) is None

    def test_watch_ref_wakes_on_advance(self, cas):
        cas.set_ref("r", "a" * 20)
        got = []
        t = threading.Thread(target=lambda: got.append(
            cas.watch_ref("r", since="a" * 20, timeout_s=5,
                          poll_interval_s=0.01)))
        t.start()
        time.sleep(0.05)
        cas.set_ref("r", "b" * 20)
        t.join(timeout=5)
        assert got == ["b" * 20]

    def test_legacy_single_line_ref_reads_epoch_zero(self, tmp_path):
        cas = DiskCAS(str(tmp_path / "cas"))
        cas.set_ref("legacy", "a" * 20)
        with open(cas._ref_path("legacy"), "w") as f:
            f.write("d" * 20)                    # pre-epoch file format
        assert cas.ref_entry("legacy") == ("d" * 20, 0)

    def test_cross_instance_watch(self, tmp_path):
        """Two DiskCAS objects on one dir = the dual-process topology."""
        a = DiskCAS(str(tmp_path / "cas"))
        b = DiskCAS(str(tmp_path / "cas"))
        a.set_ref("r", "a" * 20, epoch=1)
        assert b.ref_entry("r") == ("a" * 20, 1)
        got = []
        t = threading.Thread(target=lambda: got.append(
            b.watch_ref("r", since="a" * 20, timeout_s=5,
                        poll_interval_s=0.01)))
        t.start()
        time.sleep(0.05)
        a.set_ref("r", "b" * 20, epoch=1)
        t.join(timeout=5)
        assert got == ["b" * 20]
        # and b's stale write is fenced by a's epoch bump
        a.set_ref("r", "b" * 20, epoch=2, expect_epoch=1)
        with pytest.raises(RefFencedError):
            b.set_ref("r", "c" * 20, epoch=1)


# ---------------------------------------------------------------------------
# journal epoch plumbing
# ---------------------------------------------------------------------------
class TestJournalEpoch:
    def test_journal_adopts_stored_epoch(self):
        cas = CAS()
        j = EventJournal(cas, batch_size=1)
        assert j.epoch == 0
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        cas.set_ref(HEAD_REF, cas.get_ref(HEAD_REF), epoch=4,
                    expect_epoch=0)
        assert EventJournal(cas).epoch == 4

    def test_stale_epoch_flush_fenced(self):
        cas = CAS()
        j = EventJournal(cas, batch_size=1)
        j.on_event(E.WorkflowSubmitted(time=0.0, dag_id="d", tenant="t"))
        head = cas.get_ref(HEAD_REF)
        cas.set_ref(HEAD_REF, head, epoch=1, expect_epoch=0)
        zombie = EventJournal(cas, batch_size=1, epoch=0)
        with pytest.raises(RefFencedError):
            zombie.on_event(E.WorkflowSubmitted(time=1.0, dag_id="z",
                                                tenant="t"))
        assert cas.get_ref(HEAD_REF) == head     # chain untouched
        # the current-epoch owner keeps appending
        current = EventJournal(cas, batch_size=1)
        current.on_event(E.WorkflowSubmitted(time=2.0, dag_id="k",
                                             tenant="t"))
        assert cas.get_ref(HEAD_REF) != head

    def test_claim_fences_prior_owner(self):
        """Ownership is an explicit epoch bump, not ref adoption — so a
        supervisor-restarted copy of a fenced primary cannot silently
        regain write access by re-reading the current epoch."""
        cas = CAS()
        j1 = EventJournal(cas, batch_size=1)
        assert j1.claim() == 1
        j1.on_event(E.WorkflowSubmitted(time=0.0, dag_id="a", tenant="t"))
        j2 = EventJournal(cas, batch_size=1)
        assert j2.epoch == 1                 # adoption alone is read-grade
        assert j2.claim() == 2               # ...ownership is the bump
        j2.on_event(E.WorkflowSubmitted(time=1.0, dag_id="b", tenant="t"))
        with pytest.raises(RefFencedError):
            j1.on_event(E.WorkflowSubmitted(time=2.0, dag_id="c",
                                            tenant="t"))


# ---------------------------------------------------------------------------
# follower tailing ≡ restore, at every segment boundary
# ---------------------------------------------------------------------------
SCHEDULE = [("submit", 0, 0), ("pump", 8), ("submit", 1, 0), ("drain",),
            ("submit", 2, 1), ("submit", 0, 2), ("pump", 20), ("cancel", 2),
            ("drain",), ("compact", 1), ("submit", 1, 3), ("drain",)]


def _event_sourced_projection(svc) -> dict:
    """What a follower can (and must) reproduce of a live primary: per-job
    feeds and per-tenant usage accounting — engine-local meters (pool
    stats, latency percentiles) are process state, not replicated state."""
    tenants = sorted({r.tenant for r in svc.jobs.values()})

    def usage(t):
        return {k: v for k, v in svc.usage(t).items()
                if k not in ("pool", "latency")}

    return {
        "feeds": {jid: {k: v for k, v in svc.events(jid).items()
                        if k != "status"}
                  for jid in sorted(svc.jobs)},
        "usage": {t: usage(t) for t in tenants},
    }


class TestFollowerTailing:
    def test_equivalence_at_every_segment_boundary(self, tmp_path):
        """Dual-process topology on disk: the primary's FabricService writes
        through one DiskCAS instance, the follower tails a *separate*
        DiskCAS instance over the same directory. After every schedule step
        (journal flushed => segment boundary) the follower reproduces the
        primary's event-sourced state — feeds, usage accounting, terminal
        job views — and at every quiescent boundary (drain) it additionally
        equals a fresh ``restore_from_journal`` byte for byte. (Mid-flight
        the two legally differ: restore interrupts live jobs, a follower
        keeps them open — they are still running on the primary.)"""
        primary_cas = DiskCAS(str(tmp_path / "cas"))
        svc = build_service(primary_cas, batch_size=3)
        follower = FollowerFabric(DiskCAS(str(tmp_path / "cas")),
                                  batch_size=3)
        quiescent = 0
        for step in SCHEDULE:
            run_schedule(svc, [step])
            svc.journal.flush()              # a durable segment boundary
            follower.catch_up()
            assert _event_sourced_projection(follower.view) == \
                _event_sourced_projection(svc), f"diverged after {step}"
            for jid, rec in svc.jobs.items():
                primary_view = svc.job(jid)
                if primary_view["status"] in ("completed", "cancelled",
                                              "rejected"):
                    assert follower.view.job(jid) == primary_view, step
                else:                        # live on the primary
                    assert follower.view.job(jid)["status"] == "queued"
            if step == ("drain",):
                quiescent += 1
                assert observe(follower.view) == observe(
                    restore_fresh(primary_cas)), f"diverged after {step}"
        assert quiescent == 3
        status = follower.replication_status()
        assert status["caught_up"] is True
        assert status["lag"] == {"segments": 0, "bytes": 0, "events": 0}

    def test_lag_reporting(self):
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        follower = FollowerFabric(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        caught = follower.replication_status()
        assert caught["caught_up"] and caught["lag"]["events"] == 0
        run_schedule(svc, [("submit", 1, 1), ("drain",)])
        behind = follower.replication_status()
        assert not behind["caught_up"]
        assert behind["lag"]["segments"] > 0
        assert behind["lag"]["bytes"] > 0
        assert behind["lag"]["events"] > 0
        follower.catch_up()
        assert follower.replication_status()["lag"]["events"] == 0

    def test_rebootstrap_after_primary_compacts_past_follower(self):
        """A compaction cut beyond the follower's position forces a snapshot
        re-bootstrap; state still equals a fresh restore."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        follower = FollowerFabric(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        run_schedule(svc, [("submit", 1, 1), ("submit", 2, 2), ("drain",)])
        svc.compact(keep_segments=0)         # folds events follower lacks
        out = follower.catch_up()
        assert out["bootstrapped"] is True
        assert follower.bootstraps == 1
        assert observe(follower.view) == observe(restore_fresh(cas))

    def test_retention_firing_on_primary_mid_tail(self):
        """Scheduled retention (auto compact + gc) fires on the primary
        while the follower is behind: the follower comes back through the
        v2 snapshot, applies its own windows, and its cursors surface the
        ``feed_truncated`` markers — never silent loss (checked against the
        uncompacted shadow's ground-truth feeds)."""
        retention = RetentionPolicy(feed_window=4, compact_every_segments=4,
                                    keep_segments=1)
        svc, shadow = dual_service(batch_size=3, retention=retention)
        cas = svc.journal.cas
        follower = FollowerFabric(cas, retention=retention, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        # primary keeps going: enough history that maybe_retain fires
        run_schedule(svc, [("submit", 1, 1), ("submit", 2, 2), ("drain",),
                           ("submit", 0, 3), ("submit", 1, 0), ("drain",)])
        assert svc.auto_compactions > 0      # retention really fired
        shadow.flush()
        follower.catch_up()
        assert observe(follower.view) == observe(
            restore_fresh(cas, retention=retention))
        # ground truth: the untrimmed shadow feeds
        full = restore_fresh(cas, ref="shadow-head")
        truncated = 0
        for jid in follower.view.jobs:
            resp = follower.view.events(jid, since=-1)
            assert_cursor_contract(resp, full._feeds.get(jid, []), -1)
            truncated += bool(resp.get("truncated"))
        assert truncated > 0                 # windows actually truncated

    def test_follower_adopts_operator_doc_changes(self):
        """Quota + retention written through by the primary (the operator
        API path) are live-adopted by an unpinned follower on catch-up."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        follower = FollowerFabric(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower.catch_up()
        svc.set_quota("newco", TenantQuota(weight=7.0))
        svc.set_retention(RetentionPolicy(feed_window=2))
        follower.catch_up()
        assert follower.admission.quotas["newco"].weight == 7.0
        assert follower.retention.feed_window == 2
        for feed in follower.state.feeds.values():
            assert len(feed) <= 2

    def test_config_propagates_without_journal_traffic(self):
        """Operator-config writes move their own ref, not the journal head;
        the reload path the tail loop runs on idle wake-ups must adopt them
        even when no segment ever flushes."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower = FollowerFabric(cas, batch_size=3)
        follower.catch_up()
        head = cas.get_ref(HEAD_REF)
        svc.set_retention(RetentionPolicy(feed_window=1))  # no append
        assert cas.get_ref(HEAD_REF) == head
        assert follower._maybe_reload_config() is True
        follower._sync_view()
        assert follower.retention.feed_window == 1
        assert follower.view.retention_policy.feed_window == 1
        for feed in follower.state.feeds.values():
            assert len(feed) <= 1
        assert follower._maybe_reload_config() is False    # idempotent


# ---------------------------------------------------------------------------
# promotion
# ---------------------------------------------------------------------------
class TestPromotion:
    def _primary_with_history(self, cas):
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("submit", 1, 1), ("drain",),
                           ("submit", 2, 2), ("drain",)])
        return svc

    def test_promote_after_kill_serves_same_state(self, tmp_path):
        primary_cas = DiskCAS(str(tmp_path / "cas"))
        svc = self._primary_with_history(primary_cas)
        pre_kill = observe(svc)
        pre_usage = {t: svc.admission.usage_snapshot(t)
                     for t in ("acme", "globex", "initech")}
        del svc                              # the kill (journal is drained)
        follower = FollowerFabric(DiskCAS(str(tmp_path / "cas")),
                                  batch_size=3)
        follower.catch_up()
        promoted = follower.promote()
        assert promoted.journal.epoch == 1
        assert primary_cas.ref_entry(HEAD_REF)[1] == 1
        post = observe(promoted)
        # engine-local meters (pool stats, latency percentiles) die with the
        # old process; everything event-sourced must match exactly
        for jid, view in pre_kill["jobs"].items():
            assert post["jobs"][jid] == view
        assert post["lineage"] == pre_kill["lineage"]
        assert post["feeds"] == pre_kill["feeds"]
        for t, u in pre_usage.items():
            assert promoted.admission.usage_snapshot(t) == u
        # read-write: new work runs and journals under the new epoch
        job = promoted.submit(spec_doc("acme", "after-promote"))
        promoted.run_until_idle()
        assert promoted.job(job["job_id"])["status"] == "completed"

    def test_promote_interrupts_in_flight_work(self):
        """Jobs live at the moment of the kill close out through the
        existing interrupt-on-restart path on the promoted fabric."""
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("pump", 4)])
        svc.journal.flush()                  # mid-flight durable history
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        [rec] = promoted.jobs.values()
        assert rec.cancelled and rec.error == "interrupted by fabric restart"

    def test_zombie_primary_is_fenced(self):
        cas = CAS()
        svc = self._primary_with_history(cas)
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        head = cas.get_ref(HEAD_REF)
        with pytest.raises(RefFencedError):  # zombie flush refused
            run_schedule(svc, [("submit", 0, 3), ("drain",)])
        assert cas.get_ref(HEAD_REF) == head
        with pytest.raises(RefFencedError):  # zombie compaction refused too
            svc.compact(keep_segments=0)
        assert cas.get_ref(HEAD_REF) == head
        # the promoted primary still owns the chain
        promoted.submit(spec_doc("globex", "post-fence"))
        promoted.run_until_idle()
        assert cas.get_ref(HEAD_REF) != head
        assert cas.ref_entry(HEAD_REF)[1] == 1

    def test_promote_on_empty_journal_still_takes_an_epoch(self):
        """No head to swap yet, but the promoted journal must carry epoch 1
        so an epoch-0 writer loses as soon as the chain materializes."""
        cas = CAS()
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        assert promoted.journal.epoch == 1
        promoted.submit(spec_doc("acme", "first"))
        promoted.run_until_idle()
        assert cas.ref_entry(HEAD_REF)[1] == 1
        stale = EventJournal(cas, batch_size=1, epoch=0)
        with pytest.raises(RefFencedError):
            stale.on_event(E.WorkflowSubmitted(time=0.0, dag_id="z",
                                               tenant="t"))

    def test_promote_is_idempotent(self):
        cas = CAS()
        self._primary_with_history(cas)
        follower = FollowerFabric(cas, batch_size=3)
        promoted = follower.promote()
        assert follower.promote() is promoted

    def test_promotion_crash_matrix(self):
        """Kill the promotion at every put/set_ref boundary of the swap:
        before the fence lands the old entry must be fully intact (the
        zombie primary is still the owner); wherever it dies, a retry
        converges to a promoted state equal to a fresh restore."""
        for op, after in (("set_ref", 0), ("set_ref", 1), ("put", 0)):
            inner = CAS()
            svc = self._primary_with_history(inner)
            pre_entry = inner.ref_entry(HEAD_REF)
            proxy = CrashingCAS(inner)
            follower = FollowerFabric(proxy, batch_size=3)
            follower.catch_up()
            proxy.arm(op, after)
            with pytest.raises(Crash):
                follower.promote()
            assert follower.promoted is None
            if (op, after) == ("set_ref", 0):
                # died before the fence: ownership never moved
                assert inner.ref_entry(HEAD_REF) == pre_entry
                run_schedule(svc, [("submit", 0, 3), ("drain",)])  # still ok
            else:
                # died after the fence: the zombie is already cut off
                assert inner.ref_entry(HEAD_REF)[1] == pre_entry[1] + 1
                with pytest.raises(RefFencedError):
                    run_schedule(svc, [("submit", 0, 3), ("drain",)])
            proxy.disarm()
            promoted = follower.promote()    # the retry
            assert promoted.journal.epoch >= 1
            assert observe(promoted) == observe(restore_fresh(inner)), \
                (op, after)


# ---------------------------------------------------------------------------
# the follower HTTP surface (in-process handler table)
# ---------------------------------------------------------------------------
class TestFollowerAPI:
    def _pair(self, cas=None):
        cas = cas if cas is not None else CAS()
        svc = build_service(cas, batch_size=3)
        run_schedule(svc, [("submit", 0, 0), ("drain",)])
        follower = FollowerFabric(cas, batch_size=3)
        follower.catch_up()
        return svc, follower, FollowerAPI(follower)

    def test_reads_served_writes_409(self):
        svc, follower, api = self._pair()
        code, jobs = api.handle("GET", "/jobs")
        assert code == 200 and len(jobs["jobs"]) == 1
        jid = jobs["jobs"][0]["job_id"]
        code, job = api.handle("GET", f"/jobs/{jid}")
        assert code == 200 and job == svc.job(jid)
        code, feed = api.handle("GET", f"/jobs/{jid}/events?since=-1")
        assert code == 200 and feed["events"]
        code, repl = api.handle("GET", "/admin/replication")
        assert code == 200 and repl["role"] == "follower"
        for method, path in (("POST", "/workflows"),
                             ("POST", f"/jobs/{jid}/cancel"),
                             ("POST", "/admin/compact"),
                             ("PUT", "/admin/retention"),
                             ("PUT", "/tenants/acme/quota"),
                             ("POST", "/pump")):
            code, err = api.handle(method, path, {})
            assert code == 409 and err["error"] == "read_only_follower", path

    def test_promote_flips_read_write(self):
        svc, follower, api = self._pair()
        promoted_cb = []
        api.on_promoted = promoted_cb.append
        code, out = api.handle("POST", "/admin/promote", {})
        assert code == 200 and out["promoted"] and out["epoch"] == 1
        assert promoted_cb == [follower.promoted]
        code, repl = api.handle("GET", "/admin/replication")
        assert code == 200 and repl["role"] == "primary"
        assert repl["journal"]["epoch"] == 1
        code, job = api.handle("POST", "/workflows", {
            "spec": spec_doc("acme", "rw")})
        assert code == 201, job
        code, out2 = api.handle("POST", "/admin/promote", {})
        assert code == 409 and out2["error"] == "already_primary"
        # operator API now writes through (was 409 pre-promote)
        code, ret = api.handle("PUT", "/admin/retention", {"feed_window": 8})
        assert code == 200 and ret["policy"]["feed_window"] == 8

    def test_primary_api_replication_and_promote(self):
        cas = CAS()
        svc = build_service(cas, batch_size=3)
        api = FabricAPI(svc)
        code, repl = api.handle("GET", "/admin/replication")
        assert code == 200 and repl["role"] == "primary"
        code, err = api.handle("POST", "/admin/promote", {})
        assert code == 409 and err["error"] == "already_primary"
