"""Bounded-footprint fabric: policy-driven retention, windowed feeds,
scheduled compaction, and the long-horizon soak suite (DESIGN.md §9).

Covers:
  * ``RetentionPolicy`` in the shared fold: terminal-job eviction and feed
    windowing, applied identically by the live service and replay — a
    retention-trimmed restore equals a retention-trimmed replay (fixed,
    seed-randomized, and hypothesis-generated schedules);
  * the feed truncation contract: a cursor that predates the window start
    observes exactly one ``feed_truncated`` marker, never silent loss;
  * scheduled retention: the pump loop triggers compact+gc on segment/byte
    thresholds with a ``keep_segments`` floor, crash-proven at every
    put/set_ref boundary (restore falls back to the previous head with no
    usage divergence);
  * the CAS-rooted operator document: offline compaction folds with the
    same quotas + retention the live fabric used (flag > doc > default);
  * gc reporting (``reclaimed_blobs``/``reclaimed_bytes``) through the CLI
    and POST /admin/gc;
  * the soak suite: ≥2,000 jobs per scheduling policy with auto-compaction
    on — journal bytes, CAS blob count, and restored-state size plateau
    (strictly sublinear in job count) while tenant usage stays exact.
    Tiering: `pytest -m soak` runs the full suite, `--soak-quick` the ~10s
    CI slice (tests/conftest.py).
"""
import json
import os
import random
import subprocess
import sys

import pytest

from repro.core.cas import CAS, DiskCAS
from repro.core.control_plane import EngineConfig, FlowMeshEngine
from repro.core.journal import EventJournal
from repro.core.scheduler import POLICIES
from repro.core.simulator import SimExecutor
from repro.fabric import (FabricAPI, FabricService, RetentionPolicy,
                          TRUNCATED_KIND, configured_admission,
                          configured_retention, load_operator_doc,
                          snapshot_fold)

from harness import (QUOTAS, SHADOW_REF, TENANTS, Crash, CrashingCAS,
                     assert_cursor_contract, assert_restores_equal,
                     build_service, clone_cas, dual_service, observe,
                     restore_fresh, run_schedule, spec_doc)

UNBOUNDED = RetentionPolicy(max_terminal_jobs=None, feed_window=None)


def _usage(svc, tenant):
    """Usage snapshot minus runtime-only scheduling counters (inflight is
    reset on restore; holds are metered at the pool boundary, never
    journaled)."""
    u = svc.admission.usage_snapshot(tenant)
    u["ops"].pop("inflight"), u["ops"].pop("held")
    return u


# ---------------------------------------------------------------------------
# the policy object
# ---------------------------------------------------------------------------
def test_retention_policy_validation_and_roundtrip():
    for bad in (dict(feed_window=0), dict(max_terminal_jobs=-1),
                dict(keep_segments=-1), dict(compact_every_bytes=0),
                dict(compact_every_segments=0),
                dict(compact_every_segments=2, keep_segments=2)):
        with pytest.raises(ValueError):
            RetentionPolicy(**bad)
    pol = RetentionPolicy(max_terminal_jobs=None, feed_window=9,
                          compact_every_bytes=1 << 20, keep_segments=3)
    assert RetentionPolicy.from_dict(pol.to_dict()) == pol
    assert pol.auto_compaction
    assert not RetentionPolicy().auto_compaction


def test_int_retention_backcompat_and_config_precedence():
    svc = FabricService(seed=1, retention=2)
    assert svc.retention_policy.max_terminal_jobs == 2
    assert svc.retention_source == "flag"
    cfg_pol = RetentionPolicy(max_terminal_jobs=123)
    via_cfg = FabricService(seed=1, config=EngineConfig(seed=1,
                                                        retention=cfg_pol))
    assert via_cfg.retention_policy is cfg_pol
    assert via_cfg.retention_source == "engine-config"
    plain = FabricService(seed=1)
    assert plain.retention_policy == RetentionPolicy()
    assert plain.retention_source == "default"


# ---------------------------------------------------------------------------
# feed windowing: the truncation-marker contract
# ---------------------------------------------------------------------------
def test_feed_truncation_marker_semantics():
    svc = FabricService(seed=7, retention=RetentionPolicy(feed_window=3),
                        device_classes=("h100-nvl-94g",))
    jid = svc.submit(spec_doc("acme", "w0"))["job_id"]
    svc.run_until_idle()
    resp = svc.events(jid)
    assert resp["truncated"] is True
    assert resp["events"][0]["kind"] == TRUNCATED_KIND
    assert len(resp["events"]) == 4                 # marker + window
    marker = resp["events"][0]
    assert marker["dropped"] == 3                   # 6 feed events, kept 3
    assert all(e["seq"] > marker["seq"] for e in resp["events"][1:])

    # the marker is consumed exactly once: resuming at the returned cursor
    # (or at the marker's own seq) never replays it
    assert svc.events(jid, since=resp["cursor"])["events"] == []
    at_mark = svc.events(jid, since=marker["seq"])
    assert "truncated" not in at_mark
    assert at_mark["events"] == resp["events"][1:]

    # a cursor inside the window resumes gap-free, no marker
    mid = resp["events"][2]["seq"]
    resume = svc.events(jid, since=mid)
    assert "truncated" not in resume
    assert resume["events"] == resp["events"][3:]

    # pagination: the marker rides outside `limit` and only on page one
    page = svc.events(jid, limit=1)
    assert [e["kind"] for e in page["events"]][0] == TRUNCATED_KIND
    assert len(page["events"]) == 2
    page2 = svc.events(jid, since=page["cursor"], limit=1)
    assert TRUNCATED_KIND not in [e["kind"] for e in page2["events"]]


def test_terminal_eviction_live_and_restored_with_exact_usage():
    pol = RetentionPolicy(max_terminal_jobs=2)
    cas = CAS()
    svc = build_service(cas, retention=pol)
    for i in range(8):
        svc.submit(spec_doc("acme", f"e{i}"))
        svc.run_until_idle()
    assert len(svc.jobs) <= 4                   # cap + hysteresis slack
    svc.journal.flush()
    restored = restore_fresh(cas, retention=pol)
    assert len(restored.jobs) == 2              # fold trims to the cap
    assert all(restored.job(j)["status"] == "completed"
               for j in restored.jobs)
    assert restored._feeds.keys() == restored.jobs.keys()
    # eviction never touches accounting: all 8 submissions still counted
    live, rest = _usage(svc, "acme"), _usage(restored, "acme")
    assert live == rest
    assert live["workflows"]["submitted"] == 8
    assert live["workflows"]["completed"] == 8


def test_v1_snapshot_loads_with_migration():
    """A chain compacted by the pre-retention release (snapshot format 1)
    must still restore: v2 keys default to empty, terminal order falls back
    to record order, and the loader's policy is enforced on the result."""
    from repro.fabric import ReplayState
    cas = CAS()
    svc = build_service(cas, quotas={})
    for i in range(3):
        svc.submit(spec_doc("acme", f"v{i}"))
        svc.run_until_idle()
    svc.journal.flush()
    state = ReplayState()
    for e in svc.journal.replay():
        state.apply(e)
    blob = state.to_blob()
    for key in ("feed_trunc", "terminal", "retention"):
        blob.pop(key)
    blob["format"] = 1
    fresh = ReplayState(retention=RetentionPolicy(max_terminal_jobs=2))
    fresh.load(blob)
    assert len(fresh.jobs) == 2 and len(fresh.terminal) == 2
    with pytest.raises(ValueError, match="snapshot format"):
        ReplayState().load({"format": 999})


def test_live_eviction_follows_terminal_order():
    """Live eviction walks the terminal-transition queue (not submission
    order), so the survivors agree with a restored fold — a job evicted
    live can never resurrect after a restart."""
    pol = RetentionPolicy(max_terminal_jobs=2)
    cas = CAS()
    svc = build_service(cas, retention=pol, quotas={})
    a = svc.submit(spec_doc("acme", "ta"))["job_id"]
    b = svc.submit(spec_doc("acme", "tb"))["job_id"]
    svc.cancel(b)                       # b goes terminal before a
    svc.run_until_idle()                # a completes second
    c = svc.submit(spec_doc("acme", "tc"))["job_id"]
    svc.run_until_idle()
    d = svc.submit(spec_doc("acme", "td"))["job_id"]   # tips the hysteresis
    # terminal order is b, a, c: the cap of 2 drops b — a, though submitted
    # first, went terminal later and survives
    assert b not in svc.jobs
    assert a in svc.jobs and c in svc.jobs and d in svc.jobs
    svc.run_until_idle()
    svc.journal.flush()
    restored = restore_fresh(cas, quotas={}, retention=pol)
    # the fold evicts in the same order (b, then a once d lands); nothing
    # the live fabric dropped comes back
    assert set(restored.jobs) == {c, d}
    assert set(restored.jobs) <= set(svc.jobs)


def test_trimmed_restore_equals_trimmed_replay_fixed_schedule():
    pol = RetentionPolicy(max_terminal_jobs=3, feed_window=2)
    svc, shadow = dual_service(retention=pol)
    run_schedule(svc, [("submit", 0, 0), ("submit", 1, 0), ("pump", 9),
                       ("submit", 2, 1), ("cancel", 2), ("drain",),
                       ("compact", 1), ("submit", 0, 2), ("drain",),
                       ("submit", 1, 3), ("drain",), ("compact", 0)])
    svc.journal.flush()
    shadow.flush()
    obs = assert_restores_equal(svc.engine.cas, retention=pol)
    assert len(obs["jobs"]) <= 4                # trimmed, not full history
    for feed in obs["feeds"].values():
        real = [e for e in feed["events"] if e["kind"] != TRUNCATED_KIND]
        assert len(real) <= 2


def test_snapshot_stops_growing_with_history():
    pol = RetentionPolicy(max_terminal_jobs=3, feed_window=3)
    cas = CAS()
    svc = build_service(cas, retention=pol, quotas={})

    def burn(n):
        for i in range(n):
            svc.submit(spec_doc("acme", f"pl{i % 4}"))
            svc.run_until_idle()
        svc.journal.flush()
        return svc.compact()

    first = burn(12)
    second = burn(24)                           # 3x the history folded in
    size1 = cas.size_of(first["snapshot"])
    size2 = cas.size_of(second["snapshot"])
    assert size2 <= size1 * 1.2                 # bounded by caps, not jobs


# ---------------------------------------------------------------------------
# scheduled retention: the pump-driven compact + gc
# ---------------------------------------------------------------------------
AUTO = RetentionPolicy(max_terminal_jobs=5, feed_window=4,
                       compact_every_segments=4, keep_segments=1)


def test_scheduled_compaction_by_segments_bounds_the_chain():
    cas = CAS()
    svc = build_service(cas, retention=AUTO)    # batch_size=3
    for i in range(12):
        svc.submit(spec_doc(TENANTS[i % 3], f"sc{i % 2}"))
        svc.run_until_idle()
    assert svc.auto_compactions >= 2
    stats = svc.journal.chain_stats()
    assert stats["snapshot"] is True
    # the chain never outgrows threshold + snapshot node (+1 slack for the
    # segment that tips the trigger)
    assert stats["segments"] <= AUTO.compact_every_segments + 2
    # gc rode along: dead segments were swept, the store stays small
    assert svc.last_retention is not None and "gc" in svc.last_retention
    assert len(cas) <= 40
    status = svc.retention_status()
    assert status["auto_compactions"] == svc.auto_compactions
    assert status["policy"] == AUTO.to_dict()
    assert status["journal"]["segments"] == stats["segments"]


def test_scheduled_compaction_by_bytes():
    pol = RetentionPolicy(max_terminal_jobs=5, feed_window=4,
                          compact_every_bytes=1500, keep_segments=1)
    cas = CAS()
    svc = build_service(cas, retention=pol)
    for i in range(8):
        svc.submit(spec_doc(TENANTS[i % 3], f"b{i % 2}"))
        svc.run_until_idle()
    assert svc.auto_compactions >= 1
    assert svc.journal.bytes_since_compact < 1500 + 2500  # tail stays small


def test_restore_syncs_trigger_counters():
    """A restarted service must see the chain it inherited as un-folded
    tail — not sleep through its first scheduled compaction."""
    cas = CAS()
    svc = build_service(cas)                    # no auto-compaction
    for i in range(6):
        svc.submit(spec_doc(TENANTS[i % 3], f"rs{i}"))
        svc.run_until_idle()
    svc.journal.flush()
    segments = svc.journal.chain_stats()["segments"]
    assert segments >= AUTO.compact_every_segments
    svc2 = restore_fresh(cas, retention=AUTO)
    assert svc2.journal.segments_since_compact == segments
    out = svc2.maybe_retain()
    assert out is not None
    assert out["compact"]["folded_segments"] >= 1
    assert svc2.auto_compactions == 1


def test_scheduled_compaction_never_thrashes_at_the_floor():
    pol = RetentionPolicy(compact_every_bytes=1, keep_segments=2)
    cas = CAS()
    svc = build_service(cas, retention=pol)
    svc.submit(spec_doc("acme", "fl"))
    svc.run_until_idle()
    svc.journal.flush()
    before = svc.auto_compactions
    chain = svc.journal.chain_stats()["segments"]
    for _ in range(3):
        svc.pump(max_steps=0)
    if chain <= pol.keep_segments:
        assert svc.auto_compactions == before   # nothing foldable: no-op
    else:
        # it fired once, then the tail sits at the floor and stays quiet
        svc.pump(max_steps=0)
        assert svc.auto_compactions <= before + 1


# ---------------------------------------------------------------------------
# crash sites: pump-triggered compaction dies mid-write
# ---------------------------------------------------------------------------
CRASH_ARMS = [("snapshot put", ("put", 0)),
              ("tail rewrite put", ("put", 1)),
              ("head set_ref", ("set_ref", 0))]


@pytest.mark.parametrize("label,arm", CRASH_ARMS,
                         ids=[c[0] for c in CRASH_ARMS])
def test_pump_triggered_compact_crash_falls_back(label, arm):
    """Kill the scheduled compaction at each put/set_ref boundary: the head
    never advances, a fresh restore equals the pre-crash restore (usage
    included), and the retried trigger converges."""
    base = RetentionPolicy(max_terminal_jobs=6, feed_window=3)
    auto = RetentionPolicy(max_terminal_jobs=6, feed_window=3,
                           compact_every_segments=3, keep_segments=1,
                           gc_on_compact=False)
    inner = CAS()
    cas = CrashingCAS(inner)
    svc = build_service(cas, retention=base)    # schedule disarmed for setup
    for i in range(4):
        svc.submit(spec_doc(TENANTS[i % 3], f"cr{i}"))
        svc.run_until_idle()
    svc.journal.flush()
    assert svc.journal.segments_since_compact > auto.compact_every_segments
    svc.retention_policy = auto                 # arm the schedule
    pre = clone_cas(inner)
    head_before = svc.journal.head
    cas.arm(*arm)
    with pytest.raises(Crash):
        svc.pump(max_steps=0)                   # the retention hook fires
    assert svc.journal.head == head_before      # fell back: ref untouched
    after = observe(restore_fresh(inner, retention=base))
    before = observe(restore_fresh(pre, retention=base))
    assert after == before                      # no divergence, usage incl.
    # the next pump retries cleanly on the surviving chain
    out = svc.maybe_retain()
    assert out is not None and out["compact"]["folded_segments"] >= 1
    assert svc.auto_compactions == 1
    inner.gc()                                  # sweep the crash orphans
    assert observe(restore_fresh(inner, retention=base)) == before


# ---------------------------------------------------------------------------
# the operator document: offline agreement + precedence
# ---------------------------------------------------------------------------
def test_operator_doc_write_through_and_gc_root():
    pol = RetentionPolicy(max_terminal_jobs=7, feed_window=5)
    cas = CAS()
    svc = build_service(cas, retention=pol)     # set_quota writes through
    doc = load_operator_doc(cas)
    assert doc is not None
    assert doc["retention"] == pol.to_dict()
    assert doc["admission"]["quotas"]["acme"]["weight"] == 2.0
    adm = configured_admission(doc)
    assert adm.quotas["globex"].weight == 0.5
    assert configured_retention(doc) == pol
    # precedence: a live flag beats the document
    override = RetentionPolicy(max_terminal_jobs=1)
    assert configured_retention(doc, override=override) is override
    # the document's named ref roots it through gc
    key = cas.get_ref("operator-config")
    cas.gc()
    assert key in cas and cas.get_ref("operator-config") == key


def test_offline_compact_with_operator_doc_agrees_with_live():
    """The tentpole agreement property: an offline process that knows only
    what the CAS carries (journal + operator document) compacts to a
    snapshot that restores identically to the uncompacted shadow."""
    pol = RetentionPolicy(max_terminal_jobs=4, feed_window=3)
    svc, shadow = dual_service(retention=pol)
    run_schedule(svc, [("submit", 0, 0), ("submit", 1, 0), ("pump", 9),
                       ("submit", 2, 1), ("drain",), ("submit", 0, 2),
                       ("drain",)])
    svc.journal.flush()
    shadow.flush()
    cas = svc.engine.cas
    doc = load_operator_doc(cas)
    offline = EventJournal(cas)                 # a fresh process, same ref
    stats = offline.compact(
        snapshot_fold(configured_admission(doc),
                      retention=configured_retention(doc)),
        keep_segments=1)
    assert stats["folded_segments"] > 0
    assert_restores_equal(cas, retention=pol)


def test_cli_retention_flags_compact_and_gc_reporting(tmp_path):
    """End to end through scripts/fabric_cli.py: flags persist into the
    operator document, offline compact folds under it, and gc reports
    nonzero reclamation in its payload."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ,
           "PYTHONPATH": os.path.join(root, "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}
    cli = os.path.join(root, "scripts", "fabric_cli.py")
    casdir = str(tmp_path / "cas")

    def run(*args):
        out = subprocess.run([sys.executable, cli, *args], env=env, cwd=root,
                             capture_output=True, text=True, timeout=120)
        assert out.returncode == 0, out.stderr
        return out.stdout

    run("submit", "--template", "distill", "--param", "tenant=acme",
        "--journal", casdir, "--retention-jobs", "7", "--feed-window", "5")
    run("submit", "--template", "distill", "--param", "tenant=globex",
        "--journal", casdir)
    status = json.loads(run("retention", "--journal", casdir))
    assert status["policy"]["max_terminal_jobs"] == 7    # doc carried it
    assert status["policy"]["feed_window"] == 5
    assert status["source"] == "operator-doc"
    folded = json.loads(run("compact", "--journal", casdir, "--keep", "0"))
    assert folded["folded_segments"] > 0
    swept = json.loads(run("gc", "--journal", casdir))
    assert swept["reclaimed_blobs"] > 0
    assert swept["reclaimed_bytes"] > 0


def test_cli_restore_applies_and_preserves_operator_quotas(tmp_path):
    """A CLI restart over a journaled store must fold with the document's
    quota weights and must NOT clobber the document with defaults."""
    casdir = str(tmp_path / "cas")
    cas = DiskCAS(casdir)
    svc = build_service(cas, retention=RetentionPolicy(max_terminal_jobs=9))
    svc.submit(spec_doc("acme", "oq"))
    svc.run_until_idle()
    svc.journal.flush()
    assert load_operator_doc(cas)["admission"]["quotas"]["acme"]["weight"] \
        == 2.0
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ,
           "PYTHONPATH": os.path.join(root, "src")
           + os.pathsep + os.environ.get("PYTHONPATH", "")}
    out = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "fabric_cli.py"),
         "submit", "--template", "distill", "--param", "tenant=globex",
         "--journal", casdir],
        env=env, cwd=root, capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "restored" in out.stdout
    doc = load_operator_doc(DiskCAS(casdir))
    assert doc["admission"]["quotas"]["acme"]["weight"] == 2.0
    assert doc["admission"]["quotas"]["globex"]["weight"] == 0.5
    assert doc["retention"]["max_terminal_jobs"] == 9


def test_admin_retention_and_gc_routes():
    svc = build_service(CAS(), retention=AUTO)
    api = FabricAPI(svc)
    for i in range(6):
        code, _ = api.handle("POST", "/workflows",
                             {"spec": spec_doc("acme", f"rt{i}")})
        assert code == 201
        api.handle("POST", "/drain", {})
    code, status = api.handle("GET", "/admin/retention")
    assert code == 200
    assert status["policy"] == AUTO.to_dict()
    assert status["auto_compactions"] >= 1
    assert status["journal"]["snapshot"] is True
    code, stats = api.handle("POST", "/admin/gc")
    assert code == 200
    assert {"reclaimed_blobs", "reclaimed_bytes"} <= stats.keys()
    # a journal-less fabric still reports its policy, minus chain stats
    api2 = FabricAPI(FabricService(seed=1))
    code, bare = api2.handle("GET", "/admin/retention")
    assert code == 200 and "journal" not in bare


# ---------------------------------------------------------------------------
# property: retention-trimmed restore == retention-trimmed replay, and the
# cursor contract holds at every resume point
# ---------------------------------------------------------------------------
def _cursor_points(full_feed):
    seqs = [e["seq"] for e in full_feed]
    picks = {-1}
    if seqs:
        picks.update((seqs[0], seqs[len(seqs) // 2], seqs[-1]))
    return sorted(picks)


def _check_feed_contract(cas, pol, batch_size=3):
    """Against the untrimmed shadow ground truth: every cursor into every
    retained job's windowed feed resumes gap-free or sees one marker."""
    full = restore_fresh(cas, ref=SHADOW_REF, batch_size=batch_size,
                         retention=UNBOUNDED)
    trimmed = restore_fresh(cas, batch_size=batch_size, retention=pol)
    for jid in trimmed.jobs:
        full_feed = full.events(jid)["events"]
        for since in _cursor_points(full_feed):
            assert_cursor_contract(trimmed.events(jid, since=since),
                                   full_feed, since)


def test_property_retention_schedules_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hypothesis.given, hypothesis.settings

    step = st.one_of(
        st.tuples(st.just("submit"), st.integers(0, 2), st.integers(0, 3)),
        st.tuples(st.just("pump"), st.integers(1, 14)),
        st.tuples(st.just("cancel"), st.integers(0, 5)),
        st.tuples(st.just("compact"), st.integers(0, 2)),
    )

    @given(st.lists(step, min_size=1, max_size=12), st.integers(1, 5),
           st.integers(1, 4),
           st.one_of(st.none(), st.integers(2, 6)))
    @settings(max_examples=40, deadline=None)
    def prop(schedule, batch_size, window, cap):
        pol = RetentionPolicy(max_terminal_jobs=cap, feed_window=window)
        svc, shadow = dual_service(batch_size=batch_size, retention=pol)
        run_schedule(svc, [("submit", 0, 0), *schedule, ("drain",)])
        svc.journal.flush()
        shadow.flush()
        assert_restores_equal(svc.engine.cas, batch_size=batch_size,
                              retention=pol)
        _check_feed_contract(svc.engine.cas, pol, batch_size=batch_size)

    prop()


@pytest.mark.parametrize("seed", range(4))
def test_retention_schedules_no_hypothesis_fallback(seed):
    pol = RetentionPolicy(max_terminal_jobs=3, feed_window=2)
    svc, shadow = dual_service(seed=seed, retention=pol)
    run_schedule(svc, [("submit", 0, 0),
                       *random_schedule_steps(random.Random(seed))])
    svc.journal.flush()
    shadow.flush()
    assert_restores_equal(svc.engine.cas, retention=pol)
    _check_feed_contract(svc.engine.cas, pol)


def random_schedule_steps(rng, steps=10):
    out = []
    for _ in range(steps):
        r = rng.random()
        if r < 0.4:
            out.append(("submit", rng.randrange(3), rng.randrange(4)))
        elif r < 0.7:
            out.append(("pump", rng.randrange(1, 12)))
        elif r < 0.8:
            out.append(("cancel", rng.randrange(5)))
        else:
            out.append(("compact", rng.randrange(3)))
    out.append(("drain",))
    return out


# ---------------------------------------------------------------------------
# the soak suite: bounded footprint under continuous operation
# ---------------------------------------------------------------------------
SOAK = RetentionPolicy(max_terminal_jobs=40, feed_window=4,
                       max_result_index=60,
                       compact_every_segments=8, keep_segments=2)


def _footprint(svc, cas):
    stats = svc.journal.chain_stats()
    return {
        "chain_bytes": stats["bytes"],
        "chain_segments": stats["segments"],
        "cas_blobs": len(cas),
        "jobs": len(svc.jobs),
        "feed_events": sum(len(f) for f in svc._feeds.values()),
        "flushed_total": svc.journal.bytes_flushed,
    }


def _soak(policy_name, n_jobs, seed=11):
    """Drive one scheduling policy through ``n_jobs`` workflows on a live,
    journaled, auto-compacting fabric; verify the footprint plateaus and a
    post-soak restore reproduces tenant usage exactly."""
    cas = CAS()
    engine = FlowMeshEngine(policy=POLICIES[policy_name](),
                            executor=SimExecutor(seed=seed), cas=cas,
                            config=EngineConfig(seed=seed,
                                                telemetry_window=256))
    engine.bootstrap_workers(["h100-nvl-94g", "rtx4090-24g"])
    journal = EventJournal(cas, batch_size=64)
    svc = FabricService(engine=engine, journal=journal, retention=SOAK)
    for t, q in QUOTAS.items():
        svc.set_quota(t, q)
    half = n_jobs // 2
    checkpoints = []
    for i in range(n_jobs):
        job = svc.submit(spec_doc(TENANTS[i % len(TENANTS)], f"s{i % 23}"))
        if i % 41 == 40:
            svc.cancel(job["job_id"])           # occasional churn
        svc.pump(max_steps=48)
        if i + 1 in (half, n_jobs):
            svc.run_until_idle()
            svc.journal.flush()
            svc.maybe_retain()
            checkpoints.append(_footprint(svc, cas))
    mid, end = checkpoints

    # --- bounded footprint: the second half added ~n_jobs/2 workflows but
    # the durable chain, the store, and the state all plateau -------------
    for key in ("chain_bytes", "cas_blobs", "jobs", "feed_events"):
        assert end[key] <= mid[key] * 1.35 + 64, (policy_name, key,
                                                  mid, end)
    # strictly sublinear in total history: the chain holds a small constant
    # factor of the retention caps, not of everything ever flushed (the
    # factor loosened from 3 to 2.5 when the snapshot started carrying the
    # trace fold — more retained state per job, still O(caps) not O(jobs),
    # and flushed_total keeps growing linearly while chain_bytes plateaus)
    assert end["chain_bytes"] < end["flushed_total"] / 2.5, (policy_name, end)
    assert end["jobs"] <= SOAK.max_terminal_jobs + 8    # cap + live slack
    assert svc.auto_compactions >= 2

    # --- the observability plane is as bounded as the state (PR 6) -------
    # label cardinality: fabric_events_total carries (kind, tenant) and the
    # kind alphabet is fixed, so its series count is ≤ tenants × kinds; no
    # metric may exceed the registry's hard overflow cap either way
    card = svc.metrics.cardinality()
    from repro.core import events as E_mod
    n_kinds = len({cls.kind for cls in vars(E_mod).values()
                   if isinstance(cls, type)
                   and issubclass(cls, E_mod.FabricEvent)})
    # fixed alphabet: tenants plus the "-" series for tenant-less events
    assert 0 < card["fabric_events_total"] <= (len(TENANTS) + 1) * n_kinds
    for name, n in card.items():
        assert n <= svc.metrics.max_label_sets, (policy_name, name, n)
    # span trees: windowed to feed_window ops (≤2 spans each) + workflow +
    # admit + at most one truncation marker, per job — never O(history)
    for jid in svc.jobs:
        n_spans = svc._trace.span_count(jid)
        assert n_spans <= 3 + 2 * SOAK.feed_window, (jid, n_spans)
    # archived tombstones recycle at the same cap the job map does
    assert len(svc.archived) <= SOAK.max_terminal_jobs

    # --- a restarted fabric agrees exactly on usage ----------------------
    restored = FabricService(
        engine=_fresh_engine(policy_name, cas, seed),
        journal=EventJournal(cas, batch_size=64), retention=SOAK)
    for t, q in QUOTAS.items():
        restored.set_quota(t, q)
    stats = restored.restore_from_journal()
    assert stats["from_snapshot"] > 0
    total = {"submitted": 0, "completed": 0, "cancelled": 0, "rejected": 0}
    for t in TENANTS:
        assert _usage(restored, t) == _usage(svc, t), (policy_name, t)
        for k in total:
            total[k] += _usage(svc, t)["workflows"][k]
    assert total["submitted"] == n_jobs
    assert total["completed"] + total["cancelled"] == n_jobs
    # restored state is as bounded as the live fabric's
    assert len(restored.jobs) <= SOAK.max_terminal_jobs
    for feed in restored._feeds.values():
        assert len(feed) <= SOAK.feed_window


def _fresh_engine(policy_name, cas, seed):
    engine = FlowMeshEngine(policy=POLICIES[policy_name](),
                            executor=SimExecutor(seed=seed), cas=cas,
                            config=EngineConfig(seed=seed,
                                                telemetry_window=256))
    engine.bootstrap_workers(["h100-nvl-94g", "rtx4090-24g"])
    return engine


@pytest.mark.soak
@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_soak_full(policy_name):
    """The acceptance soak: ≥2,000 jobs per policy with auto-compaction."""
    _soak(policy_name, 2000)


@pytest.mark.soak_quick
@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_soak_quick(policy_name):
    """The ~10s CI slice of the soak (scripts/ci.sh --soak-quick)."""
    _soak(policy_name, 260)
