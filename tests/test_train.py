"""Training substrate tests: optimizers descend, losses behave, checkpoints
round-trip through the CAS, grad accumulation is exact."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cas import CAS
from repro.models.transformer import build_model
from repro.train.checkpoint import Checkpointer
from repro.train.data import DataConfig, SyntheticLM, preference_batch
from repro.train.losses import dpo_loss, ppo_loss, reward_model_loss
from repro.train.optimizer import OptimizerConfig, build_optimizer
from repro.train.train_step import build_train_step, init_train_state


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(n_layers=2, d_model=64,
                                            vocab_size=256, d_ff=128)
    model = build_model(cfg)
    data = SyntheticLM(DataConfig(vocab_size=256, seq_len=32, global_batch=8))
    return cfg, model, data


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_descends(setup, opt_name):
    cfg, model, data = setup
    opt = build_optimizer(OptimizerConfig(
        name=opt_name, peak_lr=3e-3, warmup=5, total_steps=200,
        momentum=(opt_name == "adafactor")))
    state = init_train_state(model, opt, jax.random.key(0))
    step = jax.jit(build_train_step(model, opt))
    losses = []
    for i in range(40):
        state, m = step(state, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, \
        f"{opt_name} failed to descend: {losses[:3]} -> {losses[-3:]}"


def test_grad_accum_matches_full_batch(setup):
    cfg, model, data = setup
    opt = build_optimizer(OptimizerConfig(peak_lr=1e-3, warmup=1))
    state0 = init_train_state(model, opt, jax.random.key(1))
    batch = data.batch(0)
    s_full = jax.jit(build_train_step(model, opt))
    s_acc = jax.jit(build_train_step(model, opt, grad_accum=4))
    st1, m1 = s_full(jax.tree.map(jnp.copy, state0), batch)
    st2, m2 = s_acc(jax.tree.map(jnp.copy, state0), batch)
    # losses match to fp32 accumulation tolerance
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-5)
    for a, b in zip(jax.tree.leaves(st1["params"]),
                    jax.tree.leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=3e-4, atol=3e-5)


def test_checkpoint_roundtrip_and_dedup(setup):
    cfg, model, data = setup
    opt = build_optimizer(OptimizerConfig(peak_lr=1e-3))
    state = init_train_state(model, opt, jax.random.key(2))
    cas = CAS()
    ckpt = Checkpointer(cas, "test-run")
    h1 = ckpt.save(state, step=0)
    restored, step, _ = ckpt.restore(h1)
    assert step == 0
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # saving the identical state again stores zero new leaf bytes
    before = cas.bytes_written
    ckpt.save(state, step=0)
    assert cas.bytes_written == before


def test_checkpoint_resume_is_deterministic(setup):
    cfg, model, data = setup
    opt = build_optimizer(OptimizerConfig(peak_lr=1e-3, warmup=2))
    step_fn = jax.jit(build_train_step(model, opt))

    state = init_train_state(model, opt, jax.random.key(3))
    cas = CAS()
    ckpt = Checkpointer(cas, "resume")
    for i in range(3):
        state, _ = step_fn(state, SyntheticLM(DataConfig(256, 32, 8)).batch(i))
    mhash = ckpt.save(state, step=3)
    # continue 2 more steps
    ref = state
    for i in range(3, 5):
        ref, _ = step_fn(ref, SyntheticLM(DataConfig(256, 32, 8)).batch(i))
    # crash + restore + replay the same data steps (stateless pipeline)
    restored, step, _ = ckpt.restore(mhash)
    for i in range(step, 5):
        restored, _ = step_fn(restored,
                              SyntheticLM(DataConfig(256, 32, 8)).batch(i))
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_dpo_loss_prefers_chosen(setup):
    cfg, model, _ = setup
    params = model.init(jax.random.key(4))
    ref = jax.tree.map(jnp.copy, params)
    batch = preference_batch(cfg.vocab_size, 16, 4, step=0)
    l0 = dpo_loss(model, params, ref, batch)
    # at params == ref the DPO margin is 0 -> loss == log(2)
    np.testing.assert_allclose(float(l0), np.log(2.0), rtol=1e-5)
    g = jax.grad(lambda p: dpo_loss(model, p, ref, batch))(params)
    assert any(float(jnp.abs(x).max()) > 0 for x in jax.tree.leaves(g))


def test_ppo_loss_clip_behavior(setup):
    cfg, model, data = setup
    params = model.init(jax.random.key(5))
    b = data.batch(0)
    B, T = b["tokens"].shape
    h = model._trunk(params, params["embed"][b["tokens"]])
    logits = h @ params["lm_head"]
    from repro.train.losses import token_logprobs
    old_lp = token_logprobs(logits, b["labels"])
    batch = {"tokens": b["tokens"], "labels": b["labels"],
             "old_logprobs": old_lp,
             "advantages": jnp.ones((B, T)), "mask": jnp.ones((B, T))}
    # ratio == 1 everywhere => loss == -mean(adv) == -1
    l = ppo_loss(model, params, batch)
    np.testing.assert_allclose(float(l), -1.0, rtol=1e-5)


def test_reward_model_loss_finite(setup):
    cfg, model, _ = setup
    params = model.init(jax.random.key(6))
    batch = preference_batch(cfg.vocab_size, 16, 4, step=1)
    l = reward_model_loss(model, params, batch)
    assert np.isfinite(float(l))


def test_data_pipeline_stateless_and_shardable():
    data = SyntheticLM(DataConfig(1000, 64, 16, seed=7))
    b1 = data.batch(5)
    b2 = data.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding partitions the batch deterministically
    h0 = data.batch(5, host_id=0, n_hosts=2)
    assert h0["tokens"].shape[0] == 8
