"""Unit tests: workflow DAG state machine + lineage."""
import pytest

from repro.core.cas import CAS
from repro.core.dag import (OperatorSpec, OpState, OpType, Ref, WorkflowDAG)


def chain():
    return WorkflowDAG([
        OperatorSpec("a", OpType.GENERATE, "llama-3.2-1b", inputs=["p0"]),
        OperatorSpec("b", OpType.TOOL, inputs=[Ref("a")]),
        OperatorSpec("c", OpType.GENERATE, "llama-3.2-1b",
                     inputs=[Ref("b"), "p0"]),
    ])


def test_cycle_detection():
    with pytest.raises(ValueError):
        WorkflowDAG([
            OperatorSpec("a", OpType.TOOL, inputs=[Ref("b")]),
            OperatorSpec("b", OpType.TOOL, inputs=[Ref("a")]),
        ])


def test_unknown_ref_rejected():
    with pytest.raises(ValueError):
        WorkflowDAG([OperatorSpec("a", OpType.TOOL, inputs=[Ref("nope")])])


def test_duplicate_names_rejected():
    with pytest.raises(ValueError):
        WorkflowDAG([OperatorSpec("a", OpType.TOOL),
                     OperatorSpec("a", OpType.TOOL)])


def test_frontier_progression():
    dag, cas = chain(), CAS()
    ready = dag.refresh_ready(cas)
    assert ready == ["a"]
    assert dag.state["b"] is OpState.PENDING
    # completing a unblocks b; b unblocks c
    out_a = cas.put(b"out-a")
    dag.complete("a", out_a, executed=True, worker="w0", now=1.0)
    assert dag.refresh_ready(cas) == ["b"]
    dag.complete("b", cas.put(b"out-b"), executed=True, worker="w0", now=2.0)
    assert dag.refresh_ready(cas) == ["c"]
    dag.complete("c", cas.put(b"out-c"), executed=True, worker="w1", now=3.0)
    assert dag.done
    assert dag.latency == 3.0


def test_h_task_uses_upstream_output_hash():
    cas = CAS()
    d1, d2 = chain(), chain()
    d1.refresh_ready(cas)
    d2.refresh_ready(cas)
    # identical specs + identical literal inputs -> identical H_task
    assert d1.h_task["a"] == d2.h_task["a"]
    d1.complete("a", cas.put(b"same"), executed=True, worker=None, now=0)
    d2.complete("a", cas.put(b"same"), executed=True, worker=None, now=0)
    d1.refresh_ready(cas)
    d2.refresh_ready(cas)
    assert d1.h_task["b"] == d2.h_task["b"]   # same lineage -> dedupable


def test_h_task_diverges_with_different_upstream():
    cas = CAS()
    d1, d2 = chain(), chain()
    d1.refresh_ready(cas)
    d2.refresh_ready(cas)
    d1.complete("a", cas.put(b"one"), executed=True, worker=None, now=0)
    d2.complete("a", cas.put(b"two"), executed=True, worker=None, now=0)
    d1.refresh_ready(cas)
    d2.refresh_ready(cas)
    assert d1.h_task["b"] != d2.h_task["b"]


def test_lineage_records_replay_order():
    dag, cas = chain(), CAS()
    dag.refresh_ready(cas)
    dag.complete("a", cas.put(b"1"), executed=True, worker="w", now=1.0)
    dag.refresh_ready(cas)
    dag.complete("b", cas.put(b"2"), executed=False, worker=None, now=2.0)
    dag.refresh_ready(cas)
    dag.complete("c", cas.put(b"3"), executed=True, worker="w", now=3.0)
    replay = dag.replay_order()
    assert [l.op for l in replay] == ["a", "b", "c"]
    assert replay[1].executed is False             # cache-satisfied
    assert all(l.output_hash for l in replay)      # prospective provenance
