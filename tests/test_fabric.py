"""Integration tests: the full FlowMesh engine — dedup, batching, crash
recovery, wrong-resource-spec resubmission, speculation, elasticity."""
import pytest

from repro.core.autoscaler import AutoscalerConfig
from repro.core.backends import VastAiBackend
from repro.core.control_plane import EngineConfig, FlowMeshEngine
from repro.core.dag import OperatorSpec, OpType, Ref, WorkflowDAG
from repro.core.scheduler import POLICIES, FlowMeshScheduler
from repro.core.simulator import FaultInjector, SimExecutor
from repro.core.workloads import WorkloadCfg, WorkloadGen


def small_engine(policy=None, elastic=False, max_workers=8, **cfg):
    eng = FlowMeshEngine(
        policy=policy or FlowMeshScheduler(),
        executor=SimExecutor(seed=7),
        autoscaler=AutoscalerConfig(enabled=elastic, max_workers=max_workers,
                                    idle_timeout_s=60.0),
        config=EngineConfig(seed=7, **cfg))
    eng.bootstrap_workers(["h100-nvl-94g", "rtx4090-48g", "rtx4090-24g"])
    return eng


def identical_workflow(tag="shared"):
    return WorkflowDAG([
        OperatorSpec("gen", OpType.GENERATE, "llama-3.2-1b",
                     inputs=[f"prompt:{tag}"], tokens_in=256, tokens_out=64),
        OperatorSpec("score", OpType.SCORE, "reward-1b",
                     inputs=[Ref("gen")], tokens_in=256, tokens_out=8),
    ])


# ---------------------------------------------------------------------------
def test_identical_workflows_execute_once():
    eng = small_engine()
    for i in range(5):
        eng.submit(identical_workflow(), at=float(i))
    tel = eng.run()
    assert tel.n_tasks == 5
    # 2 distinct operators total; 10 op-instances -> 8 saved
    assert tel.executions == 2
    assert tel.dedup_savings == 8
    # every DAG records full lineage despite consolidation
    for dag in eng.dags.values():
        assert len(dag.replay_order()) == 2


def test_distinct_inputs_are_not_deduped_but_batched():
    eng = small_engine()
    for i in range(6):
        eng.submit(identical_workflow(tag=f"t{i}"), at=0.0)
    tel = eng.run()
    assert tel.n_tasks == 6
    # no identical H_task -> no dedup; but same H_exec -> the 6 gen ops and
    # the 6 score ops consolidate into few batched runs
    assert tel.dedup_savings == 0
    assert tel.executions <= 4
    assert max(tel.batch_sizes) >= 4


def test_dedup_across_time_via_result_index():
    eng = small_engine()
    eng.submit(identical_workflow(), at=0.0)
    eng.submit(identical_workflow(), at=500.0)   # long after first completes
    tel = eng.run()
    assert tel.executions == 2                   # second DAG fully cached
    assert tel.dedup_savings == 2


def test_baseline_policies_never_dedup():
    for name in ("mf", "ds", "dr"):
        eng = small_engine(policy=POLICIES[name]())
        for i in range(4):
            eng.submit(identical_workflow(), at=float(i))
        tel = eng.run()
        assert tel.n_tasks == 4
        assert tel.dedup_savings == 0, name
        expected = 4 if name == "mf" else 8      # MF: 1 mono op per DAG
        assert tel.executions == expected, name


# ---------------------------------------------------------------------------
def test_worker_crash_recovery():
    eng = small_engine(speculation=False)
    gen = WorkloadGen(WorkloadCfg(seed=3))
    for t, dag in gen.make_workload("A", 12, horizon_s=120.0):
        eng.submit(dag, at=t)
    FaultInjector.crash_worker(eng, at_s=10.0, index=0)
    tel = eng.run()
    assert tel.n_tasks == 12                       # all complete despite crash
    assert len(tel.failures_detected) >= 1
    t_detect = tel.failures_detected[0][2]
    assert t_detect <= 2 * eng.cfg.watchdog_s + 1  # bounded detection


def test_wrong_resource_spec_resubmission():
    # cost-first policy so the under-specified op lands on the cheap 24 GB
    # worker, which then proactively reports the shortage (§5.3)
    eng = small_engine(policy=FlowMeshScheduler(w_c=2.0), speculation=False)
    dag = WorkflowDAG([
        OperatorSpec("sft", OpType.SFT, "llama-3.2-3b",
                     params={"lora": False, "lr": 1e-5},
                     inputs=["data:wrongspec"], train_tokens=500_000,
                     resource_class="gpu.small"),
    ])
    # tenant claims 8 GB; full-weight 3B training truly needs ~34 GB
    FaultInjector.understate_vram(dag, "sft", claimed_gb=8.0)
    eng.submit(dag, at=0.0)
    tel = eng.run()
    assert tel.n_tasks == 1                        # completed successfully
    assert tel.retries >= 1                        # after >=1 failed placement
    assert any("resource_shortage" in f[1] for f in tel.failures_detected)
    # the control plane corrected the demand hint in place
    assert dag.ops["sft"].params["min_vram_gb"] > 30.0


def test_speculative_replica_first_publication_wins():
    eng = small_engine(speculation=True, spec_factor=1.5, spec_check_s=5.0)
    # one worker is a 10x straggler
    straggler = eng.workers[eng.bootstrap_workers(["rtx4090-24g"])[0]]
    straggler.perf_noise = 12.0
    gen = WorkloadGen(WorkloadCfg(seed=5))
    for t, dag in gen.make_workload("A", 16, horizon_s=60.0):
        eng.submit(dag, at=t)
    tel = eng.run()
    assert tel.n_tasks == 16
    # duplicates (if any raced) were discarded by content identity
    assert tel.speculative_discards <= tel.speculative_launches


# ---------------------------------------------------------------------------
def test_elastic_scale_up_and_down():
    eng = FlowMeshEngine(
        executor=SimExecutor(seed=1), backend=VastAiBackend(seed=1),
        autoscaler=AutoscalerConfig(enabled=True, min_workers=1,
                                    max_workers=10, idle_timeout_s=45.0,
                                    tick_s=10.0),
        config=EngineConfig(seed=1))
    eng.bootstrap_workers(["rtx4090-24g"])
    gen = WorkloadGen(WorkloadCfg(seed=2))
    for t, dag in gen.make_workload("A", 40, horizon_s=400.0):
        eng.submit(dag, at=t)
    tel = eng.run()
    assert tel.n_tasks == 40
    peak = max(n for _, n, _, _ in tel.scaling_trace)
    end = tel.scaling_trace[-1][1]
    assert peak > 1          # scaled up under burst
    assert end < peak        # scaled back down in the lull


def test_engine_is_deterministic():
    def run_once():
        eng = small_engine()
        gen = WorkloadGen(WorkloadCfg(seed=9))
        for t, dag in gen.make_workload("B", 10, horizon_s=200.0):
            eng.submit(dag, at=t)
        return eng.run().summary()
    assert run_once() == run_once()


def test_provenance_complete_under_consolidation():
    eng = small_engine()
    dags = [identical_workflow() for _ in range(3)]
    for i, d in enumerate(dags):
        eng.submit(d, at=float(i))
    eng.run()
    # all three DAGs share output hashes but keep per-DAG edges
    outs = {d.output_hash["gen"] for d in dags}
    assert len(outs) == 1
    for d in dags:
        assert {l.op for l in d.lineage} == {"gen", "score"}
