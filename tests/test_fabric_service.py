"""Fabric gateway tests: declarative specs, multi-tenant admission, and the
long-lived job API — the service layer in front of the engine.

Everything runs against the in-process FabricAPI handler table, the same
interface the CLI and examples use.
"""
import pytest

from repro.core.autoscaler import AutoscalerConfig
from repro.core.control_plane import EngineConfig, FlowMeshEngine
from repro.core.simulator import SimExecutor
from repro.fabric import (AdmissionController, FabricAPI, FabricService,
                          SpecError, TenantQuota, compile_spec,
                          list_templates, render_template, validate_spec)


def one_op_spec(tenant, prompt, *, model="llama-3.2-1b", max_batch=24):
    return {
        "tenant": tenant,
        "ops": [
            {"name": "gen", "op_type": "generate", "model_id": model,
             "params": {"max_batch": max_batch}, "inputs": [prompt],
             "tokens_in": 256, "tokens_out": 64},
        ],
    }


def chain_spec(tenant, tag):
    return {
        "tenant": tenant,
        "ops": [
            {"name": "gen", "op_type": "generate", "model_id": "llama-3.2-1b",
             "inputs": [f"prompt:{tag}"], "tokens_in": 256, "tokens_out": 64},
            {"name": "score", "op_type": "score", "model_id": "reward-1b",
             "inputs": [{"ref": "gen"}], "tokens_in": 256, "tokens_out": 8},
        ],
    }


def service(**kw):
    return FabricService(seed=7, **kw)


# ---------------------------------------------------------------------------
# spec validation + compilation
# ---------------------------------------------------------------------------
def test_validate_spec_reports_all_problems():
    errors = validate_spec({
        "tenant": "",
        "deadline_s": -5,
        "ops": [
            {"name": "a", "op_type": "not_a_type"},
            {"name": "a", "op_type": "generate", "tokens_in": -1},
            {"name": "b", "op_type": "sft"},                 # no model_id
            {"name": "c", "op_type": "tool", "resource_class": "gpu.huge"},
        ],
    })
    text = "\n".join(errors)
    assert "tenant" in text
    assert "deadline_s" in text
    assert "op_type" in text
    assert "duplicate" in text
    assert "tokens_in" in text
    assert "model_id" in text
    assert "resource_class" in text


def test_compile_rejects_unknown_ref_and_cycle():
    with pytest.raises(SpecError, match="unknown"):
        compile_spec({"ops": [{"name": "a", "op_type": "tool",
                               "inputs": ["@missing"]}]})
    with pytest.raises(SpecError, match="cycle"):
        compile_spec({"ops": [
            {"name": "a", "op_type": "tool", "inputs": ["@b"]},
            {"name": "b", "op_type": "tool", "inputs": ["@a"]},
        ]})


def test_ref_forms_and_literal_escape():
    dag = compile_spec({"ops": [
        {"name": "a", "op_type": "tool", "inputs": ["@@not-a-ref"],
         "resource_class": "cpu"},
        {"name": "b", "op_type": "tool", "inputs": ["@a", {"ref": "a"}],
         "resource_class": "cpu"},
    ]})
    assert dag.ops["a"].inputs == ["@not-a-ref"]      # escaped literal
    assert [r.op for r in dag.ops["b"].inputs] == ["a", "a"]


def test_templates_compile_and_are_deterministic():
    assert set(list_templates()) == {"rlhf", "distill", "agent-loop",
                                     "batch-eval"}
    for name in list_templates():
        doc = render_template(name, tenant="t0")
        dag1, dag2 = compile_spec(doc), compile_spec(render_template(
            name, tenant="t0"))
        assert list(dag1.ops) == list(dag2.ops)
        # identical docs -> identical execution identities (dedup across
        # tenants depends on this)
        for op in dag1.ops:
            assert dag1.ops[op].h_exec() == dag2.ops[op].h_exec()
    assert "sft" in compile_spec(render_template("rlhf")).ops
    assert "teach" in compile_spec(render_template("distill")).ops


def test_template_unknown_name():
    with pytest.raises(SpecError, match="unknown template"):
        render_template("nope")


# ---------------------------------------------------------------------------
# the job API: submit / query / lineage / cancel
# ---------------------------------------------------------------------------
def test_cross_tenant_dedup_through_service_api():
    api = FabricAPI(service())
    code, a = api.handle("POST", "/workflows",
                         {"spec": chain_spec("acme", "shared")})
    assert code == 201 and a["status"] in ("queued", "running")
    code, b = api.handle("POST", "/workflows",
                         {"spec": chain_spec("globex", "shared")})
    assert code == 201
    api.handle("POST", "/drain", {})

    code, ja = api.handle("GET", f"/jobs/{a['job_id']}")
    code_b, jb = api.handle("GET", f"/jobs/{b['job_id']}")
    assert ja["status"] == jb["status"] == "completed"

    # the shared ops executed once: both lineages record every op, and for
    # each op exactly one tenant's instance carries executed=True
    _, la = api.handle("GET", f"/jobs/{a['job_id']}/lineage")
    _, lb = api.handle("GET", f"/jobs/{b['job_id']}/lineage")
    ra = {l["op"]: l for l in la["lineage"]}
    rb = {l["op"]: l for l in lb["lineage"]}
    assert set(ra) == set(rb) == {"gen", "score"}
    for op in ("gen", "score"):
        assert ra[op]["output_hash"] == rb[op]["output_hash"]
        assert ra[op]["executed"] != rb[op]["executed"]    # exactly one ran

    # usage reflects the split: each tenant half executed / half deduped
    _, ua = api.handle("GET", "/tenants/acme/usage")
    _, ub = api.handle("GET", "/tenants/globex/usage")
    assert ua["ops"]["executed"] + ua["ops"]["deduped"] == 2
    assert ub["ops"]["executed"] + ub["ops"]["deduped"] == 2
    assert ua["ops"]["deduped"] + ub["ops"]["deduped"] == 2
    # shared work, shared bill: equal spend for identical workflows
    assert ua["spend"]["usd"] == pytest.approx(ub["spend"]["usd"])
    assert ua["latency"]["p50_s"] > 0


def test_submit_while_running_no_restart():
    svc = service()
    job_a = svc.submit(chain_spec("acme", "live"))
    # advance the live engine partway: run until gen completed, score not
    svc.pump(max_steps=1)
    assert svc.engine.now >= 0 and not svc.engine.idle
    steps = 0
    while svc.jobs[job_a["job_id"]].dag.state["gen"].value != "completed":
        assert svc.pump(max_steps=1) == 1, "engine went idle early"
        steps += 1
        assert steps < 500
    t_mid = svc.engine.now
    assert svc.job(job_a["job_id"])["status"] == "running"

    # submit B *while A is still running*: its gen is identical and already
    # published -> served from the result index without re-execution
    job_b = svc.submit(chain_spec("globex", "live"))
    svc.run_until_idle()
    assert svc.engine.now >= t_mid          # same clock, no restart
    assert svc.job(job_a["job_id"])["status"] == "completed"
    jb = svc.job(job_b["job_id"])
    assert jb["status"] == "completed"
    lineage_b = {l["op"]: l for l in svc.lineage(job_b["job_id"])}
    assert lineage_b["gen"]["executed"] is False
    assert svc.engine.telemetry.dedup_savings >= 2


def test_three_tenants_concurrent_acceptance():
    """The acceptance scenario: >=3 tenants, live service, quotas, dedup,
    usage — no run-to-completion restart between submissions."""
    svc = service()
    svc.set_quota("small-co", TenantQuota(max_active_workflows=1))
    api = FabricAPI(svc)

    jobs = {}
    for tenant in ("acme", "globex", "initech"):
        code, j = api.handle(
            "POST", "/workflows",
            {"template": "distill", "params": {"tenant": tenant}})
        assert code == 201
        jobs[tenant] = j
    api.handle("POST", "/pump", {"max_steps": 40})   # mid-flight...
    code, j4 = api.handle(
        "POST", "/workflows",
        {"template": "batch-eval", "params": {"tenant": "acme"}})
    assert code == 201                               # ...live submission
    code, _ = api.handle("POST", "/workflows",
                         {"template": "rlhf", "params": {"tenant": "small-co"}})
    assert code == 201
    code, rejected = api.handle(
        "POST", "/workflows",
        {"template": "rlhf", "params": {"tenant": "small-co"}})
    assert code == 429 and "max_active_workflows" in rejected["error"]

    api.handle("POST", "/drain", {})
    for tenant, j in jobs.items():
        code, done = api.handle("GET", f"/jobs/{j['job_id']}")
        assert done["status"] == "completed", tenant
    # the three identical distill teachers executed once, reused twice
    executed = deduped = 0
    for j in jobs.values():
        _, lin = api.handle("GET", f"/jobs/{j['job_id']}/lineage")
        row = {l["op"]: l for l in lin["lineage"]}["teach"]
        executed += row["executed"]
        deduped += (not row["executed"])
    assert executed == 1 and deduped == 2
    for tenant in ("acme", "globex", "initech", "small-co"):
        code, u = api.handle("GET", f"/tenants/{tenant}/usage")
        assert code == 200 and u["spend"]["usd"] > 0
    code, h = api.handle("GET", "/health")
    assert h["status"] == "ok" and h["idle"]
    assert h["dedup_savings"] >= 2


def test_cancel_job_live_and_queued():
    svc = service()
    # cancel while queued (arrival not yet processed)
    q = svc.submit(chain_spec("acme", "cancel-queued"))
    assert svc.cancel(q["job_id"])["status"] == "cancelled"
    # cancel mid-flight
    r = svc.submit(chain_spec("acme", "cancel-running"))
    svc.pump(max_steps=3)
    assert svc.job(r["job_id"])["status"] == "running"
    assert svc.cancel(r["job_id"])["status"] == "cancelled"
    tel = svc.run_until_idle()
    assert svc.engine.idle and not svc.engine.stalled
    assert svc.job(r["job_id"])["status"] == "cancelled"
    assert tel.n_tasks == 0                       # nothing ran to completion
    u = svc.usage("acme")
    assert u["workflows"]["cancelled"] == 2
    assert svc.cancel("no-such-job") is None


# ---------------------------------------------------------------------------
# admission: quota rejection, in-flight holds, fair share
# ---------------------------------------------------------------------------
def test_budget_quota_rejects_after_spend():
    svc = service()
    svc.set_quota("meter", TenantQuota(budget_usd=1e-9))
    ok = svc.submit(one_op_spec("meter", "prompt:budget-1"))
    assert ok["status"] in ("queued", "running")    # no spend yet
    svc.run_until_idle()
    assert svc.usage("meter")["spend"]["usd"] > 1e-9
    rej = svc.submit(one_op_spec("meter", "prompt:budget-2"))
    assert rej["status"] == "rejected" and "budget" in rej["error"]
    assert svc.usage("meter")["workflows"]["rejected"] == 1


def test_inflight_cap_holds_ops_at_pool_boundary():
    svc = FabricService(seed=7, device_classes=(
        "rtx4090-24g", "rtx4090-24g", "rtx4090-24g"))
    svc.set_quota("capped", TenantQuota(max_inflight_ops=1))
    # 3 independent single-op workflows on 3 idle workers: without the cap
    # they would all dispatch in the first window
    for i in range(3):
        svc.submit(one_op_spec("capped", f"prompt:cap-{i}", max_batch=1))
    max_seen = 0
    while not svc.engine.idle:
        svc.pump(max_steps=1)
        max_seen = max(max_seen, svc.admission.usage["capped"].inflight_ops)
    assert max_seen == 1
    u = svc.usage("capped")
    assert u["workflows"]["completed"] == 3
    assert u["ops"]["held"] > 0


def test_weighted_fair_share_under_skewed_load():
    def latencies(fair: bool):
        admission = AdmissionController() if fair else None
        eng = FlowMeshEngine(executor=SimExecutor(seed=3),
                             config=EngineConfig(seed=3),
                             admission=admission)
        eng.bootstrap_workers(["rtx4090-24g"])      # one worker: contention
        svc = FabricService(engine=eng) if fair else None
        submit = (svc.submit if fair
                  else lambda doc: eng.submit(compile_spec(doc)))
        # heavy floods 14 jobs, then light submits 2 — strict FIFO would
        # serve light's jobs last
        for i in range(14):
            submit(one_op_spec("heavy", f"prompt:h{i}", max_batch=1))
        for i in range(2):
            submit(one_op_spec("light", f"prompt:l{i}", max_batch=1))
        tel = eng.run_until_idle()
        per = {t: sorted(xs) for t, xs in tel.tenant_latencies.items()}
        return per["light"], per["heavy"]

    light, heavy = latencies(fair=True)
    assert len(light) == 2 and len(heavy) == 14
    # light's worst job beats the heavy tenant's median: no starvation
    assert max(light) < sorted(heavy)[len(heavy) // 2]

    light_fifo, _ = latencies(fair=False)
    # and fair share actually moved the needle vs. FIFO
    assert max(light) < max(light_fifo)


def test_inflight_cap_counts_groups_not_dedup_fanout():
    # two dedup groups, each carrying TWO of the tenant's workflow
    # instances: the cap meters physical ops, so headroom accounting and
    # inflight accounting must both see 2 — not 4
    svc = FabricService(seed=7, device_classes=(
        "rtx4090-24g", "rtx4090-24g", "rtx4090-24g"))
    svc.set_quota("fan", TenantQuota(max_inflight_ops=2))
    for tag in ("x", "x", "y", "y"):
        svc.submit(one_op_spec("fan", f"prompt:fan-{tag}", max_batch=1))
    max_seen = 0
    while not svc.engine.idle:
        svc.pump(max_steps=1)
        max_seen = max(max_seen, svc.admission.usage["fan"].inflight_ops)
    assert max_seen == 2
    u = svc.usage("fan")
    assert u["workflows"]["completed"] == 4
    assert u["ops"]["executed"] + u["ops"]["deduped"] == 4
    assert u["pool"] == {"ops_arrived": 4, "dedup_joins": 2}


def test_shared_group_not_held_when_one_tenant_has_headroom():
    svc = service()
    svc.set_quota("capped", TenantQuota(max_inflight_ops=0))  # fully gated
    free = svc.submit(chain_spec("free", "shared-hold"))
    gated = svc.submit(chain_spec("capped", "shared-hold"))
    svc.run_until_idle()
    # the capped tenant rides along on the shared group instead of blocking it
    assert svc.job(free["job_id"])["status"] == "completed"
    assert svc.job(gated["job_id"])["status"] == "completed"


def test_quota_starved_work_stalls_cleanly_and_recovers():
    """A fully-gated tenant must not livelock the fabric: the autoscaler
    ignores quota-held depth, the stall guard terminates the drive, and
    cancelling the starved job (or new progress) clears the stall."""
    admission = AdmissionController()
    eng = FlowMeshEngine(
        executor=SimExecutor(seed=5), admission=admission,
        autoscaler=AutoscalerConfig(enabled=True, min_workers=1,
                                    max_workers=10, tick_s=10.0),
        config=EngineConfig(seed=5, stall_limit_s=120.0))
    eng.bootstrap_workers(["rtx4090-24g"])
    svc = FabricService(engine=eng, admission=admission)
    svc.set_quota("gated", TenantQuota(max_inflight_ops=0))

    held = svc.submit(one_op_spec("gated", "prompt:starve"))
    svc.run_until_idle()                       # returns instead of spinning
    assert eng.stalled and not eng.idle
    assert len(eng.workers) == 1               # no lease-after-lease runaway
    assert svc.pump() == 0                     # pump() also refuses to spin
    assert svc.health()["status"] == "stalled"

    svc.cancel(held["job_id"])                 # operator unblocks the fabric
    ok = svc.submit(one_op_spec("free", "prompt:after-stall"))
    svc.run_until_idle()
    assert eng.idle and not eng.stalled
    assert svc.job(ok["job_id"])["status"] == "completed"
    assert svc.health()["status"] == "ok"


def test_late_joining_tenant_does_not_starve_incumbent():
    """WFQ start-time rule: a tenant joining mid-run enters at the system
    virtual time, so the incumbent's backlog interleaves with the
    newcomer's instead of being pushed behind all of it."""
    svc = FabricService(seed=11, device_classes=("rtx4090-24g",))
    old = [svc.submit(one_op_spec("old", f"prompt:o{i}", max_batch=1))
           for i in range(8)]
    while svc.usage("old")["workflows"]["completed"] < 4:
        assert svc.pump(max_steps=1) == 1
    t_join = svc.engine.now
    new = [svc.submit(one_op_spec("new", f"prompt:n{i}", max_batch=1))
           for i in range(4)]
    # the newcomer starts at the incumbent's clock, not at zero
    assert (svc.usage("new")["fair_share"]["vtime"]
            >= svc.usage("old")["fair_share"]["vtime"] * 0.99)
    svc.run_until_idle()
    old_after = [svc.job(j["job_id"])["completed_at"] for j in old
                 if svc.job(j["job_id"])["completed_at"] > t_join]
    new_done = [svc.job(j["job_id"])["completed_at"] for j in new]
    # at least one incumbent job completes before the newcomer's last —
    # with a zero-baseline vtime the newcomer's whole backlog would win
    assert min(old_after) < max(new_done)


def test_malformed_field_types_are_spec_errors_not_crashes():
    api = FabricAPI(service())
    for bad_op in (
            {"name": "a", "op_type": "generate", "model_id": 7,
             "inputs": ["x"]},
            {"name": "a", "op_type": "generate", "model_id": "m",
             "adapters": 5},
            {"name": "a", "op_type": "generate", "revision": 1.5},
    ):
        code, body = api.handle("POST", "/workflows",
                                {"spec": {"ops": [bad_op]}})
        assert code == 400 and body["error"] == "invalid_spec", bad_op
    code, body = api.handle("POST", "/workflows",
                            {"spec": {"name": 9, "metadata": [], "ops": [
                                {"name": "a", "op_type": "tool",
                                 "resource_class": "cpu"}]}})
    assert code == 400 and len(body["detail"]) == 2


def test_cancelled_mid_flight_work_is_still_billed():
    """Submit-and-cancel must not be a free lunch: a dispatched op whose
    only consumer cancels still ran on that tenant's behalf."""
    svc = service()
    job = svc.submit(one_op_spec("sneaky", "prompt:free-lunch"))
    while svc.admission.usage["sneaky"].inflight_ops == 0:
        assert svc.pump(max_steps=1) == 1
    svc.cancel(job["job_id"])          # detaches the sole consumer
    svc.run_until_idle()
    u = svc.usage("sneaky")
    assert u["spend"]["usd"] > 0       # the batch that ran was charged
    assert u["ops"]["inflight"] == 0
    assert u["fair_share"]["vtime"] > 0


def test_cancelled_group_is_not_resurrected_by_worker_failure():
    """cancel + worker crash must not requeue a zero-consumer ghost group
    that later re-executes for nobody."""
    svc = FabricService(
        seed=7, device_classes=("rtx4090-24g", "rtx4090-24g"),
        config=EngineConfig(seed=7, heartbeat_s=2.0, watchdog_s=5.0,
                            speculation=False))
    # long op so the crash is detected while the batch is still in flight
    job = svc.submit({"tenant": "ghost", "ops": [
        {"name": "gen", "op_type": "generate", "model_id": "llama-3.2-1b",
         "params": {"max_batch": 1}, "inputs": ["prompt:doomed"],
         "tokens_in": 4096, "tokens_out": 2048}]})
    while svc.admission.usage["ghost"].inflight_ops == 0:
        assert svc.pump(max_steps=1) == 1
    svc.cancel(job["job_id"])              # sole consumer detached
    svc.engine.inject_crash(0, at=svc.engine.now + 0.1)   # kills busy worker
    svc.run_until_idle()
    assert svc.engine.pool.depth == 0      # ghost abandoned, not requeued
    ok = svc.submit(one_op_spec("live", "prompt:after-ghost", max_batch=1))
    svc.run_until_idle()
    assert svc.job(ok["job_id"])["status"] == "completed"
    # only the live tenant's op ever executed; the ghost never came back
    assert svc.engine.telemetry.executions == 1
    assert svc.usage("ghost")["ops"]["executed"] == 0


def test_tenant_joining_during_idle_window_enters_at_clock():
    svc = service()
    svc.submit(one_op_spec("incumbent", "prompt:old-1"))
    svc.submit(one_op_spec("incumbent", "prompt:old-2"))
    svc.run_until_idle()               # incumbent accrues vtime, goes idle
    old_vt = svc.usage("incumbent")["fair_share"]["vtime"]
    assert old_vt > 0
    svc.submit(one_op_spec("newcomer", "prompt:new-1"))
    new_vt = svc.usage("newcomer")["fair_share"]["vtime"]
    assert new_vt >= old_vt * 0.99     # no zero-baseline leapfrog


def test_rejection_flood_does_not_accumulate_records():
    svc = FabricService(seed=7, retention=2)
    svc.set_quota("capped", TenantQuota(max_active_workflows=1))
    live = svc.submit(one_op_spec("capped", "prompt:live"))
    for i in range(10):
        rej = svc.submit(one_op_spec("capped", f"prompt:flood-{i}"))
        assert rej["status"] == "rejected"
    assert len(svc.jobs) <= 4          # retention + live + newest rejected
    svc.run_until_idle()
    assert svc.job(live["job_id"])["status"] == "completed"
    assert svc.usage("capped")["workflows"]["rejected"] == 10


def test_pump_and_drain_reject_non_numeric_bodies():
    api = FabricAPI(service())
    assert api.handle("POST", "/pump", {"max_steps": "10"})[0] == 400
    assert api.handle("POST", "/pump", {"until": "5"})[0] == 400
    assert api.handle("POST", "/drain", {"until": True})[0] == 400
    assert api.handle("POST", "/pump", [5])[0] == 400      # non-object body
    assert api.handle("POST", "/workflows", "spec")[0] == 400
    assert api.handle("POST", "/pump", {"max_steps": 3})[0] == 200


def test_usage_query_does_not_allocate_tenant_state():
    svc = service()
    for i in range(5):
        svc.usage(f"scanner-{i}")
    assert not svc.admission.usage                # read path stayed read-only


def test_terminal_job_retention_bounds_memory():
    svc = FabricService(seed=7, retention=2)
    ids = []
    for i in range(6):
        job = svc.submit(one_op_spec("acme", f"prompt:r{i}"))
        ids.append(job["job_id"])
        svc.run_until_idle()
    assert len(svc.jobs) <= 3                  # retention + the live one
    assert len(svc.engine.dags) <= 3
    assert svc.job(ids[0]) is None             # oldest evicted
    assert svc.lineage(ids[0]) is None
    assert svc.job(ids[-1])["status"] == "completed"
    # accounting is unaffected by eviction
    assert svc.usage("acme")["workflows"]["completed"] == 6


# ---------------------------------------------------------------------------
# API surface details
# ---------------------------------------------------------------------------
def test_api_errors_and_listing():
    api = FabricAPI(service())
    assert api.handle("GET", "/nope")[0] == 404
    assert api.handle("DELETE", "/health")[0] == 405
    assert api.handle("GET", "/jobs/unknown")[0] == 404
    assert api.handle("POST", "/jobs/unknown/cancel")[0] == 404
    code, body = api.handle("POST", "/workflows", {})
    assert code == 400
    code, body = api.handle("POST", "/workflows",
                            {"spec": {"ops": [{"name": "x"}]}})
    assert code == 400 and body["error"] == "invalid_spec"
    # tenant-supplied garbage in template params is a 400, not a crash
    code, body = api.handle("POST", "/workflows",
                            {"template": "agent-loop",
                             "params": {"rounds": "three"}})
    assert code == 400 and body["error"] == "invalid_template_params"
    code, body = api.handle("POST", "/workflows",
                            {"template": "rlhf", "params": [1, 2]})
    assert code == 400 and body["error"] == "invalid_template_params"
    code, body = api.handle("POST", "/workflows",
                            {"template": "rlhf",
                             "params": {"no_such_arg": 1}})
    assert code == 400 and body["error"] == "invalid_template_params"

    api.handle("POST", "/workflows", {"spec": one_op_spec("a", "p1")})
    api.handle("POST", "/workflows", {"spec": one_op_spec("b", "p2")})
    code, listed = api.handle("GET", "/jobs?tenant=a")
    assert code == 200 and len(listed["jobs"]) == 1
    code, listed = api.handle("GET", "/jobs")
    assert len(listed["jobs"]) == 2
    code, t = api.handle("GET", "/workflows/templates")
    assert code == 200 and "rlhf" in t["templates"]


def test_workload_generator_compiles_through_spec_path():
    from repro.core.workloads import WorkloadCfg, WorkloadGen
    gen = WorkloadGen(WorkloadCfg(seed=11))
    kinds = set()
    for builder in (gen.GROUP_A + gen.GROUP_B_EXTRA
                    + ("distill_pipeline", "batch_eval")):
        dag = getattr(gen, builder)()
        kinds.add(dag.metadata["kind"])
        assert dag.ops
    assert {"rlhf", "distill", "batch_eval", "reasoning_chain"} <= kinds
