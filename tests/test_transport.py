"""Transport seam + lease lifecycle (DESIGN.md §13) and worker-lane fixes.

Deterministic coverage drives a ``LeaseTransport`` with an injectable fake
clock through the real ``FabricService`` (journaled), playing the worker
process inline: register -> poll -> heartbeat -> complete, plus expiry and
revoke paths — then proves the journal restores to the same observation
(lease events are journaled but excluded from every fold). A final matrix
spawns two real worker processes over HTTP long-poll and kill -9s one
mid-batch: the job must complete on the survivor via ``GroupRequeued``.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import pytest

from harness import DEVICES, QUOTAS, observe, restore_fresh, spec_doc
from repro.core.cas import CAS
from repro.core.cost_model import DEVICE_CLASSES
from repro.core.dag import OperatorSpec, OpType
from repro.core.journal import EventJournal
from repro.core.scheduler import next_batch_id
from repro.core.simulator import SimExecutor
from repro.core.transport import (FencedLease, InProcessTransport,
                                  LeaseTransport, UnknownWorker,
                                  batch_from_wire, batch_to_wire,
                                  result_from_wire, result_to_wire,
                                  spec_from_wire, spec_to_wire)
from repro.core.worker import (DispatchBatch, ExecResult, ExecutionGroup,
                               ResidentSet, Worker, WorkerState)
from repro.fabric import FabricService
from repro.fabric.api import FabricAPI
from repro.fabric.http import FabricHTTPServer, RemoteAPI
from repro.fabric.service import TERMINAL_STATUSES

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# satellite: ResidentSet budget + running-total accounting
# ---------------------------------------------------------------------------
class TestResidentSet:
    def test_oversize_model_is_refused(self):
        rs = ResidentSet(10.0)                     # weight budget: 9.0 GB
        rs.touch("a", 4.0)
        assert rs.touch("big", 9.5) == []          # NOT everything-evicted
        assert not rs.has("big")
        assert rs.has("a") and rs.used_gb == 4.0   # set untouched

    def test_oversize_into_empty_set_stays_empty(self):
        rs = ResidentSet(10.0)
        assert rs.touch("big", 9.5) == []
        assert rs.used_gb == 0.0 and not rs.has("big")

    def test_lru_eviction_and_running_total(self):
        rs = ResidentSet(10.0)
        rs.touch("a", 4.0)
        rs.touch("b", 4.0)
        assert rs.used_gb == 8.0
        assert rs.touch("c", 4.0) == ["a"]         # LRU out, total stays 8
        assert rs.used_gb == 8.0
        rs.touch("b", 4.0)                         # refresh b
        assert rs.touch("d", 4.0) == ["c"]         # c is now LRU
        assert rs.has("b") and rs.has("d")
        # the running total always matches a fresh sum
        assert rs.used_gb == sum(rs._models.values())

    def test_used_never_exceeds_budget(self):
        rs = ResidentSet(10.0)
        for i in range(20):
            rs.touch(f"m{i}", 2.5)
            assert rs.used_gb <= 9.0 + 1e-9


# ---------------------------------------------------------------------------
# satellite: round-robin lane rotation + drain clears affinity state
# ---------------------------------------------------------------------------
def _shell(worker_id="w", device="rtx4090-24g", now=0.0):
    w = Worker(worker_id, DEVICE_CLASSES[device], now=now)
    w.state = WorkerState.ACTIVE
    return w


def _slice(h_exec, batch_id):
    return DispatchBatch(batch_id=batch_id, h_exec=h_exec, groups=[],
                         worker_id="w", admitted_at=0.0)


class TestLaneRotation:
    def test_round_robin_does_not_starve_later_lanes(self):
        w = _shell()
        for i, h in enumerate(("A", "A", "B", "B")):
            w.admit(_slice(h, i))
        served = [w.next_batch().h_exec for _ in range(4)]
        # the old scan-from-first-key drained lane A completely first
        assert served == ["A", "B", "A", "B"]
        assert w.next_batch() is None and w.queued_slices() == 0

    def test_emptied_lane_leaves_rotation(self):
        w = _shell()
        w.admit(_slice("A", 0))
        w.admit(_slice("B", 1))
        w.admit(_slice("B", 2))
        assert [w.next_batch().batch_id for _ in range(3)] == [0, 1, 2]
        assert not w.queues and not w._lane_order

    def test_drain_clears_lane_affinity(self):
        w = _shell()
        w.admit(_slice("A", 0))
        w.admit(_slice("B", 1))
        w.idle_since = None
        dropped = w.drain()
        assert [b.batch_id for b in dropped] == [0, 1]
        assert not w.queues and not w._lane_order
        assert w.served_execs == set()      # a retired lane is hot for nothing
        assert w.idle_since is None and w.queued_slices() == 0
        # a drained worker can be re-admitted cleanly
        w.admit(_slice("C", 2))
        assert w.next_batch().batch_id == 2


# ---------------------------------------------------------------------------
# satellite: speculative replicas get globally-unique batch ids
# ---------------------------------------------------------------------------
def test_batch_ids_are_globally_unique():
    ids = [next_batch_id() for _ in range(5)]
    assert len(set(ids)) == 5
    assert ids == sorted(ids)


# ---------------------------------------------------------------------------
# wire format round-trips
# ---------------------------------------------------------------------------
def _spec(name="gen"):
    return OperatorSpec(name=name, op_type=OpType.GENERATE,
                        model_id="llama-3.2-1b", adapters=("lora-x",),
                        params={"temperature": 0.5}, inputs=["prompt:x"],
                        tokens_in=128, tokens_out=16)


class TestWireFormat:
    def test_spec_round_trip_preserves_identity(self):
        spec = _spec()
        rt = spec_from_wire(spec_to_wire(spec))
        assert rt.h_exec() == spec.h_exec()
        assert rt.h_model == spec.h_model
        assert rt.tokens_out == spec.tokens_out
        assert rt.inputs == []          # identity travels on the group

    def test_batch_round_trip(self):
        spec = _spec()
        g = ExecutionGroup(h_task="ht", h_exec=spec.h_exec(), spec=spec,
                           input_hashes=("i1", "i2"))
        batch = DispatchBatch(batch_id=7, h_exec=spec.h_exec(), groups=[g],
                              worker_id="w9", admitted_at=1.5,
                              speculative=True)
        rt = batch_from_wire(json.loads(json.dumps(batch_to_wire(batch))))
        assert (rt.batch_id, rt.worker_id, rt.admitted_at,
                rt.speculative) == (7, "w9", 1.5, True)
        assert rt.groups[0].h_task == "ht"
        assert rt.groups[0].input_hashes == ("i1", "i2")
        assert rt.groups[0].spec.h_exec() == spec.h_exec()

    def test_result_round_trip(self):
        r = ExecResult(outputs=[b"blob", "txt"], duration_s=1.25, load_s=0.5,
                       flops=3e9, energy_j=None, failed=True,
                       failure="resource_shortage")
        rt = result_from_wire(json.loads(json.dumps(result_to_wire(r))))
        assert rt.outputs == [b"blob", b"txt"]   # bytes both ways
        assert (rt.duration_s, rt.load_s, rt.flops) == (1.25, 0.5, 3e9)
        assert rt.energy_j is None
        assert rt.failed and rt.failure == "resource_shortage"


# ---------------------------------------------------------------------------
# deterministic lease lifecycle (fake clock, worker played inline)
# ---------------------------------------------------------------------------
class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def remote_service(*, ttl=10.0, clock=None):
    cas = CAS()
    transport = LeaseTransport(lease_ttl_s=ttl, clock=clock or FakeClock())
    svc = FabricService(seed=7, cas=cas, device_classes=DEVICES,
                        journal=EventJournal(cas, batch_size=3),
                        transport=transport)
    for tenant, quota in QUOTAS.items():
        svc.set_quota(tenant, quota)
    kinds: list[str] = []
    svc.engine.bus.subscribe(lambda e: kinds.append(e.kind))
    return svc, transport, cas, kinds


def execute_lease(lease, shell):
    """What scripts/worker_main.py does with a granted lease, inline."""
    batch = batch_from_wire(lease["batch"])
    result = SimExecutor(seed=0).execute(batch, shell, None)
    spec = batch.groups[0].spec
    if spec.model_id and not result.failed:
        shell.make_resident(spec.h_model, spec.model_id)
    return result_to_wire(result)


def replay_view(svc):
    """The journal-derived surface as one JSON string: everything
    ``observe`` covers except the usage snapshot, whose latency/pool
    counters live in process-local engine telemetry that a restore never
    rebuilds (the established restore contract compares restored twins;
    here we hold the stronger claim live-vs-restored on every journal-
    folded surface)."""
    o = observe(svc)
    o.pop("usage")
    return json.dumps(o, sort_keys=True, default=str)


def drive_to_terminal(svc, transport, jid, wid, shell, *, rounds=20):
    """Pump + serve leases on ``wid`` until the job goes terminal."""
    for _ in range(rounds):
        svc.pump()
        if svc.job(jid)["status"] in TERMINAL_STATUSES:
            return svc.job(jid)["status"]
        lease = transport.poll(wid)
        if lease is not None:
            transport.complete(wid, lease["lease_id"],
                               execute_lease(lease, shell))
    raise AssertionError(f"job {jid} never went terminal: {svc.job(jid)}")


class TestLeaseLifecycle:
    def test_remote_service_skips_bootstrap_lanes(self):
        svc, transport, _, _ = remote_service()
        assert svc.engine.transport is transport
        assert svc.engine.workers == {}     # lanes join by registration

    def test_grant_heartbeat_renewal_complete_and_replay(self):
        clock = FakeClock()
        svc, t, cas, kinds = remote_service(ttl=10.0, clock=clock)
        assert t.register("w1", "h100-nvl-94g")["worker_id"] == "w1"
        jid = svc.submit(spec_doc("acme", "life"))["job_id"]
        svc.pump()
        assert "w1" in t.offers             # dispatch parked as an offer
        lease = t.poll("w1")
        assert lease is not None and "lease_granted" in kinds
        assert lease["epoch"] == 1

        # 8s in: still within TTL, tick keeps the lease
        clock.advance(8.0)
        t.tick()
        assert "w1" in t.leases
        assert t.heartbeat("w1", lease["lease_id"]) == {"ok": True,
                                                        "revoked": False}
        # 16s in: past the original deadline — only the renewal keeps it
        clock.advance(8.0)
        t.tick()
        assert "w1" in t.leases and "lease_expired" not in kinds

        shell = _shell("w1", "h100-nvl-94g")
        out = t.complete("w1", lease["lease_id"], execute_lease(lease, shell))
        assert out == {"ok": True, "revoked": False}
        status = drive_to_terminal(svc, t, jid, "w1", shell)
        assert status == "completed"
        assert "group_requeued" not in kinds    # clean path: no requeues

        # journal replay: a restored fabric reports the identical surface,
        # byte for byte — lease events replay as no-ops in every fold
        svc.journal.flush()
        assert replay_view(svc) == replay_view(restore_fresh(cas))

    def test_heartbeat_with_stale_lease_id_is_fenced(self):
        svc, t, _, _ = remote_service()
        t.register("w1", "h100-nvl-94g")
        svc.submit(spec_doc("acme", "fence"))
        svc.pump()
        lease = t.poll("w1")
        with pytest.raises(FencedLease):
            t.heartbeat("w1", lease["lease_id"] + "/stale")

    def test_poll_unregistered_worker_raises(self):
        svc, t, _, _ = remote_service()
        with pytest.raises(UnknownWorker):
            t.poll("ghost")

    def test_expiry_requeues_and_survivor_completes(self):
        clock = FakeClock()
        svc, t, cas, kinds = remote_service(ttl=5.0, clock=clock)
        t.register("w1", "h100-nvl-94g")
        jid = svc.submit(spec_doc("acme", "expire"))["job_id"]
        svc.pump()
        lease = t.poll("w1")
        assert lease is not None

        # the worker goes silent; one TTL later the lease lapses
        clock.advance(5.1)
        svc.pump()                          # pump drives transport.tick()
        assert "lease_expired" in kinds
        assert "worker_fail" in kinds     # same crash path as the watchdog
        assert "group_requeued" in kinds
        assert "w1" not in t.lanes and "w1" not in t.leases
        # the fenced holder can neither renew nor publish its stale result
        with pytest.raises(FencedLease):
            t.heartbeat("w1", lease["lease_id"])
        with pytest.raises(FencedLease):
            t.complete("w1", lease["lease_id"], {"outputs": []})

        # a replacement registers; the DEAD record keeps the old name
        wid = t.register("w1", "h100-nvl-94g")["worker_id"]
        assert wid == "w1~1"
        status = drive_to_terminal(svc, t, jid, wid,
                                   _shell(wid, "h100-nvl-94g"))
        assert status == "completed"

        svc.journal.flush()
        assert replay_view(svc) == replay_view(restore_fresh(cas))

    def test_silent_idle_lane_is_dropped(self):
        clock = FakeClock()
        svc, t, _, kinds = remote_service(ttl=2.0, clock=clock)
        t.register("w1", "h100-nvl-94g")
        clock.advance(3.1)                  # > lane_ttl (1.5 * ttl)
        t.tick()
        assert "w1" not in t.lanes
        assert "lease_expired" not in kinds     # no lease was ever granted

    def test_revoke_cancels_running_batch(self):
        svc, t, _, kinds = remote_service()
        t.register("w1", "h100-nvl-94g")
        jid = svc.submit(spec_doc("acme", "revoke"))["job_id"]
        svc.pump()
        lease = t.poll("w1")
        assert lease is not None

        svc.cancel(jid)
        assert "lease_revoked" in kinds
        assert svc.job(jid)["status"] == "cancelled"
        # the next heartbeat is the ack: the lease dies, the lane survives
        assert t.heartbeat("w1", lease["lease_id"]) == {"ok": False,
                                                        "revoked": True}
        assert "w1" not in t.leases and "w1" in t.lanes

        # the freed lane serves new work immediately
        jid2 = svc.submit(spec_doc("acme", "after-revoke"))["job_id"]
        status = drive_to_terminal(svc, t, jid2, "w1",
                                   _shell("w1", "h100-nvl-94g"))
        assert status == "completed"

    def test_revoked_lease_result_is_discarded_on_complete(self):
        svc, t, _, _ = remote_service()
        t.register("w1", "h100-nvl-94g")
        jid = svc.submit(spec_doc("acme", "revoke2"))["job_id"]
        svc.pump()
        lease = t.poll("w1")
        svc.cancel(jid)
        # worker missed the heartbeat ack and reports anyway: discarded
        shell = _shell("w1", "h100-nvl-94g")
        out = t.complete("w1", lease["lease_id"], execute_lease(lease, shell))
        assert out == {"ok": False, "revoked": True}
        assert svc.job(jid)["status"] == "cancelled"

    def test_cancel_takes_back_unclaimed_offer(self):
        svc, t, _, kinds = remote_service()
        t.register("w1", "h100-nvl-94g")
        jid = svc.submit(spec_doc("acme", "offer"))["job_id"]
        svc.pump()
        assert "w1" in t.offers
        svc.cancel(jid)
        assert "w1" not in t.offers         # never granted: just taken back
        assert "lease_revoked" in kinds
        assert t.poll("w1") is None
        assert svc.job(jid)["status"] == "cancelled"

    def test_poll_while_leased_means_worker_lost_state(self):
        svc, t, _, kinds = remote_service()
        t.register("w1", "h100-nvl-94g")
        jid = svc.submit(spec_doc("acme", "amnesia"))["job_id"]
        svc.pump()
        assert t.poll("w1") is not None
        # the process restarted without re-registering: fail the lane so the
        # batch requeues, and force a fresh registration
        with pytest.raises(UnknownWorker):
            t.poll("w1")
        svc.pump()
        assert "group_requeued" in kinds
        wid = t.register("w1", "h100-nvl-94g")["worker_id"]
        status = drive_to_terminal(svc, t, jid, wid,
                                   _shell(wid, "h100-nvl-94g"))
        assert status == "completed"


# ---------------------------------------------------------------------------
# HTTP surface: the worker endpoints refuse a non-remote fabric
# ---------------------------------------------------------------------------
class TestWorkerEndpoints:
    def test_register_refused_without_remote_transport(self):
        svc = FabricService(seed=7, cas=CAS(), device_classes=DEVICES)
        assert isinstance(svc.engine.transport, InProcessTransport)
        api = FabricAPI(svc)
        code, out = api.handle("POST", "/worker/register",
                               {"worker_id": "w1",
                                "device_class": "h100-nvl-94g"})
        assert code == 409 and out["error"] == "no_remote_transport"

    def test_register_rejects_unknown_device_class(self):
        svc, _, _, _ = remote_service()
        code, out = FabricAPI(svc).handle(
            "POST", "/worker/register",
            {"worker_id": "w1", "device_class": "tpu-v9"})
        assert code == 400 and out["error"] == "unknown_device_class"

    def test_lease_poll_unknown_worker_is_410(self):
        svc, _, _, _ = remote_service()
        code, out = FabricAPI(svc).handle("POST", "/worker/lease",
                                          {"worker_id": "ghost"})
        assert code == 410 and out["error"] == "unknown_worker"

    def test_in_process_transport_cannot_revoke(self):
        svc = FabricService(seed=7, cas=CAS(), device_classes=DEVICES)
        w = next(iter(svc.engine.workers.values()))
        assert svc.engine.transport.revoke(w) is None


# ---------------------------------------------------------------------------
# two-worker kill -9 matrix over real HTTP long-poll
# ---------------------------------------------------------------------------
def _spawn_worker(url, wid, *, slow_ms=0.0):
    env = {**os.environ, "PYTHONPATH": str(ROOT / "src")}
    return subprocess.Popen(
        [sys.executable, str(ROOT / "scripts" / "worker_main.py"),
         "--url", url, "--worker-id", wid, "--device-class", "h100-nvl-94g",
         "--poll-s", "1.0", "--slow-ms", str(slow_ms)],
        env=env, cwd=str(ROOT),
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)


def _wait(predicate, *, timeout_s=30.0, interval_s=0.05, what="condition"):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        value = predicate()
        if value:
            return value
        time.sleep(interval_s)
    raise AssertionError(f"timed out waiting for {what}")


class TestKillMatrix:
    def test_kill9_lessee_mid_batch_then_idle_survivor(self):
        transport = LeaseTransport(lease_ttl_s=1.0)
        cas = CAS()
        svc = FabricService(seed=7, cas=cas, device_classes=DEVICES,
                            journal=EventJournal(cas, batch_size=3),
                            transport=transport)
        for tenant, quota in QUOTAS.items():
            svc.set_quota(tenant, quota)
        kinds: list[str] = []
        svc.engine.bus.subscribe(lambda e: kinds.append(e.kind))
        server = FabricHTTPServer(FabricAPI(svc), auto_pump=True)
        procs: dict[str, subprocess.Popen] = {}
        try:
            with server:
                client = RemoteAPI(server.url, timeout_s=10.0)
                # slow-ms holds each batch long enough for the kill to land
                # mid-lease (heartbeats renew it until then)
                procs["ka"] = _spawn_worker(server.url, "ka", slow_ms=2500)
                procs["kb"] = _spawn_worker(server.url, "kb", slow_ms=2500)
                _wait(lambda: len(client.handle(
                    "GET", "/admin/transport")[1]["lanes"]) == 2,
                    what="both lanes registered")

                code, job = client.handle("POST", "/workflows",
                                          {"spec": spec_doc("acme", "kill9")})
                assert code == 201, job
                jid = job["job_id"]

                # case (a): kill -9 the worker holding the first lease
                leases = _wait(lambda: client.handle(
                    "GET", "/admin/transport")[1]["leases"],
                    what="first lease granted")
                victim = leases[0]["worker"]
                os.kill(procs[victim].pid, signal.SIGKILL)
                procs[victim].wait(timeout=5)

                done = _wait(lambda: (lambda d: d if d["status"]
                             in TERMINAL_STATUSES else None)(
                             client.handle("GET", f"/jobs/{jid}")[1]),
                             timeout_s=60.0, what="job terminal")
                assert done["status"] == "completed"
                # the dead lessee's batch came back via the journaled
                # requeue path and reran on the survivor
                assert "lease_expired" in kinds
                assert "group_requeued" in kinds
                assert "worker_fail" in kinds

                # case (b): kill -9 the now-idle survivor — lane death only,
                # nothing to requeue
                survivor = next(w for w in procs if w != victim)
                requeues = kinds.count("group_requeued")
                os.kill(procs[survivor].pid, signal.SIGKILL)
                procs[survivor].wait(timeout=5)
                _wait(lambda: not client.handle(
                    "GET", "/admin/transport")[1]["lanes"],
                    what="idle lane expired")
                assert kinds.count("group_requeued") == requeues

                # the restored twin tells the same story as the primary
                trace = client.handle("GET", f"/jobs/{jid}/trace")[1]
            svc.journal.flush()
            restored = restore_fresh(cas)
            assert json.dumps(trace, sort_keys=True) \
                == json.dumps(restored.trace(jid), sort_keys=True)
            assert restored.job(jid)["status"] == "completed"
        finally:
            for p in procs.values():
                if p.poll() is None:
                    p.kill()
