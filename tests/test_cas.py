"""Unit tests: content-addressable store, incl. at-most-once publication."""
import pytest

from repro.core.cas import CAS, DiskCAS, IntegrityError


def test_put_get_roundtrip():
    cas = CAS()
    key = cas.put({"a": [1, 2, 3]})
    assert cas.get(key) == {"a": [1, 2, 3]}


def test_dedup_by_content():
    cas = CAS()
    k1 = cas.put_bytes(b"hello")
    k2 = cas.put_bytes(b"hello")
    assert k1 == k2
    assert len(cas) == 1
    assert cas.dedup_hits == 1


def test_publish_first_wins():
    cas = CAS()
    k1, won1 = cas.publish(b"artifact")
    k2, won2 = cas.publish(b"artifact")    # late speculative replica
    assert k1 == k2
    assert won1 and not won2


def test_miss_raises():
    cas = CAS()
    with pytest.raises(KeyError):
        cas.get_bytes("deadbeef")


def test_disk_cas_roundtrip(tmp_path):
    cas = DiskCAS(str(tmp_path / "cas"))
    key = cas.put_bytes(b"checkpoint-bytes")
    assert key in cas
    assert cas.get_bytes(key) == b"checkpoint-bytes"
    # fresh handle over the same directory sees the artifact (durability)
    cas2 = DiskCAS(str(tmp_path / "cas"))
    assert cas2.get_bytes(key) == b"checkpoint-bytes"


def test_disk_cas_detects_corruption(tmp_path):
    cas = DiskCAS(str(tmp_path / "cas"))
    key = cas.put_bytes(b"data")
    path = cas._path(key)
    with open(path, "wb") as f:
        f.write(b"tampered")
    with pytest.raises(IntegrityError):
        cas.get_bytes(key)


def test_disk_cas_publish(tmp_path):
    cas = DiskCAS(str(tmp_path / "cas"))
    _, won1 = cas.publish(b"x")
    _, won2 = cas.publish(b"x")
    assert won1 and not won2
