"""Control-plane hot-path contracts (PR 7):

  * single-serialization event fan-out — ``FabricEvent.to_dict`` returns one
    shared dict per (event, seq), invalidated if the bus re-stamps the seq,
    and ``event_from_dict`` inverts it;
  * adaptive group commit — ``commit_latency_s`` coalesces bursts into one
    segment under a wall-clock bound with a ``max_buffer`` hard cap, while
    the default (None) keeps the legacy fixed-batch segment boundaries;
  * flush writes each segment with exactly ONE store touch (``put_sized``),
    and the reported bytes equal the stored size;
  * the journal append histogram times buffer appends only — a segment
    flush is observed by ``fabric_journal_flush_seconds``, never by
    ``fabric_journal_append_seconds``;
  * LFU/recency result-index eviction: dedup-hit counts keep re-derived
    entries over merely-recent ones, degrade exactly to the legacy
    oldest-first order when no entry has hits, stay live/replay-identical,
    and travel with the snapshot (format 4) and the trace blob (format 2).
"""
from __future__ import annotations

import time

from repro.core import events as E
from repro.core.cas import CAS
from repro.core.events import EventBus, event_from_dict
from repro.core.journal import EventJournal
from repro.core.metrics import MetricsRegistry
from repro.core.tracing import TraceState
from repro.fabric.replay import (ReplayState, RetentionPolicy,
                                 SNAPSHOT_FORMAT, trim_result_index)

from harness import build_service, spec_doc


def _ev(i: int = 0) -> E.FabricEvent:
    return E.OpReady(time=float(i), dag_id=f"d{i}", tenant="acme",
                     op="gen", h_task=f"t{i}", h_exec=f"x{i}")


# ---------------------------------------------------------------------------
# single serialization
# ---------------------------------------------------------------------------
def test_to_dict_returns_shared_instance():
    e = _ev()
    assert e.to_dict() is e.to_dict()


def test_to_dict_cache_invalidates_on_seq_restamp():
    e = _ev()
    d0 = e.to_dict()
    assert d0["seq"] == e.seq
    e.seq = 42                      # what EventBus.publish does
    d1 = e.to_dict()
    assert d1 is not d0
    assert d1["seq"] == 42
    assert e.to_dict() is d1


def test_fanout_subscribers_share_one_dict():
    bus = EventBus()
    seen: list[dict] = []
    for _ in range(3):
        bus.subscribe(lambda ev, s=seen: s.append(ev.to_dict()))
    bus.publish(_ev())
    assert len(seen) == 3
    assert seen[0] is seen[1] is seen[2]
    assert seen[0]["seq"] == 0 and seen[0]["kind"] == "op_ready"


def test_to_dict_matches_event_fields_and_roundtrips():
    e = _ev(3)
    e.seq = 7
    d = e.to_dict()
    assert d["kind"] == "op_ready" and d["dag_id"] == "d3" and d["seq"] == 7
    back = event_from_dict(dict(d))
    assert type(back) is E.OpReady
    assert back.to_dict() == d
    # unknown keys are dropped, not passed to the constructor
    assert event_from_dict({**d, "bogus": 1}).to_dict() == d


# ---------------------------------------------------------------------------
# group commit + put_sized
# ---------------------------------------------------------------------------
def test_default_journal_keeps_fixed_batch_boundaries():
    j = EventJournal(CAS(), batch_size=4)
    for i in range(9):
        j.on_event(_ev(i))
    assert j.segments_written == 2 and j.pending == 1


def test_group_commit_max_buffer_cap():
    j = EventJournal(CAS(), batch_size=4, commit_latency_s=60.0,
                     max_buffer=8)
    for i in range(20):
        j.on_event(_ev(i))
    # the latency bound never expires; only the hard cap cuts segments —
    # bursts coalesce into 8-event segments despite batch_size=4
    assert j.segments_written == 2 and j.pending == 4


def test_group_commit_zero_latency_flushes_every_event():
    j = EventJournal(CAS(), commit_latency_s=0.0)
    for i in range(5):
        j.on_event(_ev(i))
    assert j.segments_written == 5 and j.pending == 0


def test_group_commit_latency_bound():
    j = EventJournal(CAS(), commit_latency_s=0.05, max_buffer=1000)
    for i in range(3):
        j.on_event(_ev(i))
    assert j.segments_written == 0 and j.pending == 3
    time.sleep(0.06)
    j.on_event(_ev(3))              # buffer age exceeded the bound
    assert j.segments_written == 1 and j.pending == 0


class _CountingCAS(CAS):
    def __init__(self):
        super().__init__()
        self.size_of_calls = 0

    def size_of(self, key):
        self.size_of_calls += 1
        return super().size_of(key)


def test_flush_touches_store_once_per_segment():
    cas = _CountingCAS()
    j = EventJournal(cas, batch_size=2)
    for i in range(6):
        j.on_event(_ev(i))
    assert j.segments_written == 3
    # put_sized reports the stored size at write time: no stat-after-put
    assert cas.size_of_calls == 0
    assert j.bytes_flushed == sum(
        cas.size_of(k) for k in cas.keys())


def test_append_histogram_excludes_flush():
    reg = MetricsRegistry()
    j = EventJournal(CAS(), batch_size=3)
    j.metrics = reg
    for i in range(7):
        j.on_event(_ev(i))
    text = reg.render()
    assert 'fabric_journal_append_seconds_count 7' in text
    assert 'fabric_journal_flush_seconds_count 2' in text


# ---------------------------------------------------------------------------
# LFU/recency eviction
# ---------------------------------------------------------------------------
def _index(n: int) -> dict[str, str]:
    return {f"t{i}": f"k{i}" for i in range(n)}


def test_trim_without_hits_is_legacy_oldest_first():
    a, b = _index(6), _index(6)
    trim_result_index(a, 4)
    trim_result_index(b, 4, hits={})
    assert a == b == {f"t{i}": f"k{i}" for i in range(2, 6)}


def test_trim_all_zero_hits_degrades_to_legacy():
    a, b = _index(6), _index(6)
    trim_result_index(a, 3)
    trim_result_index(b, 3, hits={f"t{i}": 0 for i in range(6)})
    assert list(a) == list(b)


def test_trim_keeps_frequently_hit_over_merely_recent():
    idx = _index(6)
    hits = {"t0": 5, "t1": 2}
    trim_result_index(idx, 4, hits)
    # t2/t3 (stale, zero hits) go; the hit entries survive despite their age
    assert list(idx) == ["t0", "t1", "t4", "t5"]
    assert hits == {"t0": 5, "t1": 2}


def test_trim_pops_hits_of_evicted_entries():
    idx = _index(4)
    hits = {"t0": 1, "t1": 3, "t2": 2}
    trim_result_index(idx, 1, hits)            # evict t3 (0), t0 (1), t2 (2)
    assert list(idx) == ["t1"]
    assert hits == {"t1": 3}                   # evicted entries' hits popped


def test_dedup_hits_keep_index_entry_live_and_on_replay():
    """Submitting the same spec repeatedly under a tiny index cap: the hit
    counts must keep the re-derived entries resident, and the engine's
    (index, hits) state must equal the replay fold's at every point."""
    retention = RetentionPolicy(max_result_index=3)
    svc = build_service(CAS(), retention=retention)
    for k in range(4):                       # 4 distinct specs, 2 ops each
        svc.submit(spec_doc("acme", f"hot{k % 2}"))
        svc.run_until_idle()
    # re-derivations: every resubmission is a pure index hit
    for _ in range(3):
        svc.submit(spec_doc("acme", "hot0"))
        svc.run_until_idle()
    assert sum(svc.engine.result_index_hits.values()) > 0
    svc.journal.flush()
    state = ReplayState(retention=retention)
    base = svc.journal.base_state()
    if base is not None:
        state.load(base)
    for e in svc.journal.replay():
        state.apply(e)
    assert state.result_index == svc.engine.result_index
    assert state.result_index_hits == svc.engine.result_index_hits
    assert len(svc.engine.result_index) <= 3


def test_snapshot_format4_roundtrips_hits():
    state = ReplayState(retention=RetentionPolicy(max_result_index=8))
    state.result_index = _index(3)
    state.result_index_hits = {"t1": 4}
    blob = state.to_blob()
    assert blob["format"] == SNAPSHOT_FORMAT == 4
    fresh = ReplayState(retention=RetentionPolicy(max_result_index=8))
    fresh.load(blob)
    assert fresh.result_index_hits == {"t1": 4}
    # pre-v4 snapshots load with empty hit counts
    legacy = dict(blob, format=3)
    legacy.pop("result_index_hits")
    fresh2 = ReplayState()
    fresh2.load(legacy)
    assert fresh2.result_index_hits == {}


def test_trace_producer_hits_follow_same_policy():
    t = TraceState(max_producers=2)
    for i in range(2):
        t.apply(E.GroupCompleted(
            time=float(i), h_task=f"t{i}", h_exec="x", output_hash=f"o{i}",
            worker="w0", consumers=((f"d{i}", "op", "acme"),), seq=i))
    # a dedup edge resolves through t0's producer: hit + recency touch
    t.apply(E.WorkflowSubmitted(time=1.5, dag_id="d9", tenant="acme",
                                ops=("op",), seq=2))
    t.apply(E.DedupHit(time=2.0, dag_id="d9", tenant="acme", op="op",
                       h_task="t0", source="index", seq=3))
    assert t.producer_hits == {"t0": 1}
    assert list(t.producers) == ["t1", "t0"]          # touched to newest
    t.apply(E.GroupCompleted(
        time=3.0, h_task="t2", h_exec="x", output_hash="o2",
        worker="w0", consumers=(("d2", "op", "acme"),), seq=3))
    # cap 2: the zero-hit t1 is evicted, the hit-carrying t0 survives
    assert set(t.producers) == {"t0", "t2"}
    blob = t.to_blob()
    assert blob["format"] == 2 and blob["producer_hits"] == {"t0": 1}
    fresh = TraceState(max_producers=2)
    fresh.load(blob)
    assert fresh.producer_hits == {"t0": 1}
