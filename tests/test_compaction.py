"""Snapshot compaction, CAS garbage collection, and the single
event-sourced write path — proven by the crash/replay harness
(tests/harness.py, DESIGN.md §8).

Covers:
  * restore-from-(snapshot+tail) == restore-from-full-replay, for fixed,
    seed-randomized, and hypothesis-generated schedules with arbitrary
    compaction points;
  * crash injection at put/set_ref boundaries during flush AND compaction:
    the chain stays readable (orphan blob at worst) and gc reclaims the
    orphans;
  * gc reclaims >= the compacted segments' bytes on a DiskCAS without
    breaking any live ref (dedup keeps working after the sweep);
  * admission-as-subscriber: no imperative note_* hooks remain, and live
    usage matches journal-replayed usage across all four policies;
  * realized deadline-miss telemetry under an EDF-boosted workload.
"""
import random

import pytest

from repro.core.cas import CAS, DiskCAS
from repro.core.control_plane import EngineConfig, FlowMeshEngine
from repro.core.journal import EventJournal
from repro.core.scheduler import POLICIES
from repro.core.simulator import SimExecutor
from repro.fabric import (AdmissionController, FabricService, ReplayState,
                          snapshot_fold)

from harness import (QUOTAS, SHADOW_REF, Crash, CrashingCAS,
                     assert_restores_equal, build_service, clone_cas,
                     dual_service, observe, random_schedule, restore_fresh,
                     run_schedule, spec_doc)


# ---------------------------------------------------------------------------
# snapshot + tail == full replay
# ---------------------------------------------------------------------------
def test_compacted_restore_equals_full_replay_basic():
    svc, shadow = dual_service()
    for i in range(4):
        svc.submit(spec_doc(("acme", "globex")[i % 2], f"t{i % 2}"))
    svc.run_until_idle()
    live_usage = {t: svc.usage(t) for t in ("acme", "globex")}
    stats = svc.compact(keep_segments=1)
    assert stats["folded_segments"] > 0 and stats["snapshot"] is not None
    shadow.flush()
    obs = assert_restores_equal(svc.engine.cas)
    # the restored view agrees with what the live fabric computed
    for t in ("acme", "globex"):
        assert obs["usage"][t]["workflows"] == live_usage[t]["workflows"]
        assert obs["usage"][t]["spend"] == live_usage[t]["spend"]
        assert obs["usage"][t]["ops"] == live_usage[t]["ops"]


def test_compaction_is_incremental_and_idempotent():
    svc, shadow = dual_service()
    svc.submit(spec_doc("acme", "a"))
    svc.run_until_idle()
    first = svc.compact()
    assert first["folded_segments"] > 0
    # nothing new: a second compaction folds zero segments, head unchanged
    again = svc.compact()
    assert again["folded_segments"] == 0
    assert svc.journal.head == first["head"]
    # more history accumulates on top of the snapshot, then folds into it
    svc.submit(spec_doc("globex", "a"))      # dedups against acme's run
    svc.run_until_idle()
    second = svc.compact()
    assert second["folded_segments"] > 0
    assert second["snapshot"] != first["snapshot"]
    shadow.flush()
    assert_restores_equal(svc.engine.cas)


@pytest.mark.parametrize("seed", range(6))
def test_arbitrary_schedules_and_compaction_points(seed):
    """No-hypothesis fallback: seed-randomized interleavings of submit /
    pump / cancel / compact, compared against the uncompacted shadow."""
    svc, shadow = dual_service(seed=seed)
    run_schedule(svc, random_schedule(random.Random(seed)))
    svc.journal.flush()
    shadow.flush()
    assert_restores_equal(svc.engine.cas)


def test_property_compaction_points_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hypothesis.given, hypothesis.settings

    step = st.one_of(
        st.tuples(st.just("submit"), st.integers(0, 2), st.integers(0, 3)),
        st.tuples(st.just("pump"), st.integers(1, 14)),
        st.tuples(st.just("cancel"), st.integers(0, 5)),
        st.tuples(st.just("compact"), st.integers(0, 2)),
    )

    @given(st.lists(step, min_size=1, max_size=14), st.integers(1, 5))
    @settings(max_examples=50, deadline=None)
    def prop(schedule, batch_size):
        svc, shadow = dual_service(batch_size=batch_size)
        run_schedule(svc, [("submit", 0, 0), *schedule, ("drain",)])
        svc.journal.flush()
        shadow.flush()
        assert_restores_equal(svc.engine.cas, batch_size=batch_size)

    prop()


def test_restore_stats_report_snapshot_share():
    svc, shadow = dual_service()
    svc.submit(spec_doc("acme", "s"))
    svc.run_until_idle()
    svc.compact()
    svc.submit(spec_doc("globex", "s2"))
    svc.run_until_idle()
    svc.journal.flush()
    restored = build_service(svc.engine.cas)
    stats = restored.restore_from_journal()
    assert 0 < stats["from_snapshot"] < stats["events"]
    assert stats["jobs"] == 2


# ---------------------------------------------------------------------------
# crash injection: flush and compaction write boundaries
# ---------------------------------------------------------------------------
def crashed_workload(arm_op, *, during="flush"):
    """Run a fixed workload, arm the CAS, crash inside flush/compact.
    Returns (inner_cas, pre_crash_clone)."""
    inner = CAS()
    cas = CrashingCAS(inner)
    svc, shadow = dual_service(cas)
    svc.submit(spec_doc("acme", "c0"))
    svc.submit(spec_doc("globex", "c0"))
    svc.run_until_idle()
    svc.journal.flush()
    shadow.flush()
    if during == "compact":
        svc.submit(spec_doc("acme", "c1"))
        svc.run_until_idle()
        svc.journal.flush()
    else:
        svc.submit(spec_doc("acme", "c1"))
        svc.pump(max_steps=4)              # leave events in the buffer
    pre = clone_cas(inner)
    cas.arm(*arm_op)
    with pytest.raises(Crash):
        if during == "compact":
            svc.compact()
        else:
            svc.journal.flush()
    return inner, pre


CRASH_SITES = [
    # (label, armed boundary, phase)
    ("flush: before segment put", ("put", 0), "flush"),
    ("flush: between put and set_ref", ("set_ref", 0), "flush"),
    ("compact: before snapshot put", ("put", 0), "compact"),
    ("compact: between snapshot put and set_ref", ("set_ref", 0), "compact"),
]


@pytest.mark.parametrize("label,arm,phase",
                         CRASH_SITES, ids=[c[0] for c in CRASH_SITES])
def test_crash_leaves_readable_chain_and_gc_collects_orphans(
        label, arm, phase):
    inner, pre = crashed_workload(arm, during=phase)
    # the head never dangles: the post-crash chain replays cleanly and sees
    # exactly the history that was durable before the crash
    after = observe(restore_fresh(inner))
    before = observe(restore_fresh(pre))
    assert after == before
    # at worst the crash orphaned blobs; gc reclaims them and the chain
    # still restores identically
    orphans = len(inner) - len(pre._blobs)
    assert orphans >= (1 if arm[0] == "set_ref" else 0)
    stats = inner.gc()
    assert stats["deleted"] >= orphans
    assert observe(restore_fresh(inner)) == before


def test_crash_mid_compaction_rewrite_then_retry_succeeds():
    """Die between the tail-segment rewrites of a compaction: old chain
    intact; a retried compaction converges and equals the shadow."""
    inner = CAS()
    cas = CrashingCAS(inner)
    svc, shadow = dual_service(cas)
    for i in range(3):
        svc.submit(spec_doc("acme", f"r{i}"))
        svc.run_until_idle()
    svc.journal.flush()
    shadow.flush()
    head_before = svc.journal.head
    cas.arm("put", 1)                      # snapshot put ok; die re-chaining
    with pytest.raises(Crash):
        svc.compact(keep_segments=2)
    assert svc.journal.head == head_before     # ref never advanced
    retry = svc.compact(keep_segments=2)       # clean retry on the survivor
    assert retry["folded_segments"] > 0
    assert_restores_equal(inner)
    inner.gc()                                 # sweep the half-written blobs
    assert_restores_equal(inner)


# ---------------------------------------------------------------------------
# GC on disk: reclaim >= compacted bytes, keep every live ref working
# ---------------------------------------------------------------------------
def test_disk_gc_reclaims_compacted_segments_and_preserves_dedup(tmp_path):
    cas = DiskCAS(str(tmp_path))
    svc = build_service(cas, quotas={})
    for i in range(5):
        svc.submit(spec_doc("acme", f"g{i % 3}"))
    svc.run_until_idle()
    svc.journal.flush()
    old_segments = {k: cas.size_of(k) for k in _chain_keys(svc.journal)}
    assert len(old_segments) > 1
    pre = observe(restore_fresh(cas, quotas={}))

    svc.compact()
    stats = cas.gc()
    # every compacted segment went unreferenced and was swept
    assert stats["bytes_reclaimed"] >= sum(old_segments.values())
    assert not any(k in cas for k in old_segments)

    # no live ref broke: the snapshot restores the same view, artifacts
    # survived, and identical work still dedups across the restart
    restored = restore_fresh(cas, quotas={})
    post = observe(restored)
    assert post == pre
    for rows in post["lineage"].values():
        for row in rows:
            if row["output_hash"]:
                assert row["output_hash"] in cas
    job = restored.submit(spec_doc("acme", "g0"))
    restored.run_until_idle()
    rows = {r["op"]: r for r in restored.lineage(job["job_id"])}
    assert not rows["gen"]["executed"] and not rows["score"]["executed"]
    assert restored.engine.telemetry.executions == 0


def _chain_keys(journal):
    keys, key = [], journal.head
    while key is not None:
        keys.append(key)
        key = journal.cas.get(key)["prev"]
    return keys


def test_gc_traces_json_blobs_and_keeps_ref_rooted_chains(tmp_path):
    """Checkpoint-style state — a named ref to a JSON manifest naming leaf
    hashes — survives gc end to end; unrooted JSON blobs do not."""
    import json

    cas = DiskCAS(str(tmp_path))
    leaves = [cas.put_bytes(b"\x00tensor-bytes-%d" % i) for i in range(3)]
    manifest = cas.put_bytes(json.dumps({"leaves": leaves}).encode())
    cas.set_ref("checkpoint/run", manifest)
    stale = cas.put_bytes(json.dumps({"leaves": []}).encode())  # unrooted
    stats = cas.gc()
    assert stats["deleted"] == 1 and stale not in cas
    assert manifest in cas and all(k in cas for k in leaves)


def test_gc_keeps_inflight_literal_inputs_live():
    """POST /admin/gc mid-flight must not sweep interned literal inputs of
    ops that have not completed yet (they appear in no journaled event)."""
    cas = CAS()
    svc = build_service(cas, quotas={})
    svc.submit(spec_doc("acme", "inflight"))
    while not any(s == "ready" for s in
                  svc.job(sorted(svc.jobs)[0])["ops"].values()):
        assert svc.pump(max_steps=1) == 1
    dag = next(iter(svc.engine.dags.values()))
    interned = {h for hs in dag.input_hashes.values() for h in hs}
    assert interned
    svc.gc()
    assert all(h in cas for h in interned)
    svc.run_until_idle()
    assert svc.job(sorted(svc.jobs)[0])["status"] == "completed"


def test_gc_refuses_nothing_it_should_keep():
    """A blob is kept iff reachable: named refs root the chain, the chain
    roots the artifacts named in events/snapshots."""
    cas = CAS()
    svc = build_service(cas, quotas={})
    svc.submit(spec_doc("acme", "keep"))
    svc.run_until_idle()
    svc.journal.flush()
    n_before = len(cas)
    stats = cas.gc()
    assert stats["deleted"] == 0 and len(cas) == n_before
    orphan = cas.put_bytes(b"orphan-artifact-nobody-references")
    stats = cas.gc()
    assert stats["deleted"] == 1 and orphan not in cas


# ---------------------------------------------------------------------------
# admission is a bus subscriber: one write path for live + replay
# ---------------------------------------------------------------------------
def test_imperative_note_hooks_are_gone():
    import inspect

    from repro.core import control_plane
    from repro.fabric import service as service_mod

    for name in ("note_dispatch", "note_executed", "note_requeue",
                 "note_deduped", "note_workflow_done",
                 "note_workflow_cancelled", "replay_event"):
        assert not hasattr(AdmissionController, name), name
    # neither the engine nor the service calls an accounting hook directly
    for mod in (control_plane, service_mod):
        src = inspect.getsource(mod)
        assert "admission.note_" not in src, mod.__name__
        assert "note_dispatch" not in src and "note_executed" not in src


@pytest.mark.parametrize("policy_name", sorted(POLICIES))
def test_live_usage_matches_replayed_usage_across_policies(policy_name):
    """The PR-2 invariant, now structural: folding the journal through a
    fresh controller reproduces the live controller's accounting exactly
    (transient scheduling counters excepted) under every policy."""
    cas = CAS()
    engine = FlowMeshEngine(policy=POLICIES[policy_name](),
                            executor=SimExecutor(seed=13),
                            cas=cas, config=EngineConfig(seed=13))
    engine.bootstrap_workers(["h100-nvl-94g", "rtx4090-24g"])
    journal = EventJournal(cas, batch_size=4)
    svc = FabricService(engine=engine, journal=journal)
    for t, q in QUOTAS.items():
        svc.set_quota(t, q)
    for i in range(6):
        svc.submit(spec_doc(("acme", "globex", "initech")[i % 3],
                            f"p{i % 2}"))
    svc.pump(max_steps=40)
    svc.cancel(sorted(svc.jobs)[0])
    svc.run_until_idle()
    journal.flush()

    fold = snapshot_fold(svc.admission)(None)
    for e in journal.replay():
        fold.apply(e)
    for t in ("acme", "globex", "initech"):
        live = svc.admission.usage_snapshot(t)
        replayed = fold.admission.usage_snapshot(t)
        # inflight/held are runtime-only scheduling state (holds are metered
        # at the pool boundary, never journaled)
        for view in (live, replayed):
            view["ops"].pop("inflight"), view["ops"].pop("held")
        assert replayed == live, (policy_name, t)


def test_engine_runs_admissionless_and_emits_requeue_events():
    """The engine never *requires* a controller — and its failure path now
    narrates group requeues as events."""
    engine = FlowMeshEngine(executor=SimExecutor(seed=5),
                            config=EngineConfig(seed=5, heartbeat_s=2.0,
                                                watchdog_s=5.0,
                                                speculation=False))
    engine.bootstrap_workers(["rtx4090-24g", "rtx4090-24g"])
    seen = []
    engine.bus.subscribe(lambda e: seen.append(e.kind))
    svc = FabricService(engine=engine)
    doc = spec_doc("acme", "x", deadline_s=9000.0)
    # long op so the watchdog detects the crash while the batch is in flight
    doc["ops"][0].update(tokens_in=4096, tokens_out=2048,
                         params={"max_batch": 1})
    svc.submit(doc)
    while "dispatch" not in seen:
        assert svc.pump(max_steps=1) == 1
    engine.inject_crash(0, at=engine.now + 0.1)
    svc.run_until_idle()
    assert "worker_fail" in seen
    assert "group_requeued" in seen
    assert svc.job(sorted(svc.jobs)[0])["status"] == "completed"


# ---------------------------------------------------------------------------
# realized deadline misses (telemetry follow-on)
# ---------------------------------------------------------------------------
def test_realized_deadline_misses_counted_under_edf_load():
    svc = FabricService(seed=9, device_classes=("rtx4090-24g",))
    tight = svc.submit(spec_doc("fast-co", "edf", deadline_s=0.5))
    roomy = svc.submit(spec_doc("slow-co", "edf2", deadline_s=90000.0))
    svc.run_until_idle()
    tel = svc.engine.telemetry
    assert tel.deadline_completions == 2
    assert tel.deadline_misses == 1            # realized, not predicted
    assert tel.summary()["deadline_misses"] == 1
    assert svc.job(tight["job_id"])["deadline"]["predicted_miss"] is True
    assert svc.job(roomy["job_id"])["deadline"]["predicted_miss"] is False
    # no-SLO workloads contribute nothing
    svc2 = FabricService(seed=9)
    svc2.submit(spec_doc("acme", "no-slo"))
    svc2.run_until_idle()
    assert svc2.engine.telemetry.deadline_completions == 0
    assert svc2.engine.telemetry.deadline_misses == 0


# ---------------------------------------------------------------------------
# snapshot format guards
# ---------------------------------------------------------------------------
def test_snapshot_format_version_is_checked():
    state = ReplayState()
    with pytest.raises(ValueError, match="snapshot format"):
        state.load({"format": 999})


def test_compact_empty_and_unjournaled_service():
    svc = FabricService(seed=1)
    with pytest.raises(ValueError, match="journal"):
        svc.compact()
    cas = CAS()
    journal = EventJournal(cas)
    stats = journal.compact(snapshot_fold())
    assert stats["folded_segments"] == 0 and stats["head"] is None
